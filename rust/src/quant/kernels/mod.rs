//! Vectorized stage-1 kernels: runtime-dispatched SIMD implementations
//! of the rotate→quantize (encode) and dequantize→unrotate (decode)
//! bodies in `quant::pipeline`, with the scalar code retained verbatim
//! as the bit-exact reference and universal fallback.
//!
//! # Why this is possible
//!
//! The paper's hardware-alignment claim is that one 4D isoclinic block
//! is one SIMD register.  We exploit it in two shapes:
//!
//! * **Single-vector kernels** — blocks of one vector are independent,
//!   so 8 (AVX2) / 4 (NEON) blocks are transposed into SoA registers
//!   (all w-components in one register, …) and the quaternion sandwich
//!   runs as pure vertical arithmetic, fused with the quantizer:
//!   encoding is a rank count over the ≤15 codebook boundaries
//!   (`vcmpps`/`fcmgt` accumulate), decoding is a ≤16-entry level table
//!   lookup in shuffle registers (`vpermps`/`vqtbl4q`) instead of a
//!   per-lane `decode1` call.
//! * **Multi-vector block-major tiles** — `encode_batch` /
//!   `decode_batch_strided` process 8 (AVX2) / 4 (NEON) vectors at a
//!   time; for each block index the same lane of every register belongs
//!   to a different *vector*, so the sandwich is vertical across
//!   vectors with the block's quaternion broadcast — no lane shuffles
//!   in the math, only one 4×T transpose at the store (decode) or load
//!   (encode) boundary.  This is where KV-page gathers spend their
//!   time.
//!
//! # Bit-exactness contract
//!
//! Every SIMD path must produce *bit-identical* results to the scalar
//! reference (`rust/tests/kernel_equivalence.rs` enforces this), so
//! cache pages written under one backend decode identically under any
//! other.  Three rules make that possible:
//!
//! 1. **No FMA contraction.**  The kernels use separate IEEE-exact
//!    mul/add/sub (which round identically to the scalar code); a fused
//!    multiply-add would change the rounding.
//! 2. **Same operation order.**  `hamilton8`/`hamilton4` replicate the
//!    exact left-to-right association of `math::quaternion::hamilton`;
//!    conjugation is a sign flip (exact) applied before the product.
//! 3. **Same quantizer decisions.**  The scalar `encode1` is a
//!    branchless binary search over the ∞-padded ascending boundary
//!    array, which equals the rank `|{i : x > bounds[i]}|` — the SIMD
//!    compare-accumulate computes that rank directly (NaN compares
//!    false in both, ties break identically).  `decode1` is a pure
//!    table select, reproduced by the in-register lookup bit for bit.
//!
//! # Dispatch safety contract
//!
//! The AVX2 functions are `unsafe fn` annotated
//! `#[target_feature(enable = "avx2")]`.  The *only* call sites are the
//! `match` arms below, which are reached exclusively when
//! [`KernelBackend::resolve`] returned [`Resolved::Avx2`] — and that
//! happens only after `std::arch::is_x86_feature_detected!("avx2")`
//! succeeded at `Stage1` construction time.  NEON is architecturally
//! mandatory on aarch64, so `Resolved::Neon` needs no runtime probe.
//! All SIMD loads/stores use the unaligned intrinsics; slice bounds are
//! asserted in the safe wrappers before any raw pointer is formed, so
//! the `unsafe` surface is exactly "the CPU executes this instruction
//! set", never memory safety.
//!
//! Variants with non-power-of-two blocks (`Dense`, `Grouped8D`) always
//! take the scalar reference path regardless of the configured backend.
//! `Rotor3D` runs scalar under the default `RotorImpl::Multivector`
//! (which deliberately models the baseline's 8-component expansion
//! cost) but has a 3-blocks-per-iteration SIMD path under
//! `RotorImpl::OddIntermediate`, so Table-2 speedup comparisons stay
//! honest as the iso paths get faster.
//!
//! `Avx512` adds a 16-vector block-major tile (`quant::kernels::avx512`)
//! whose level-table lookup is a single full-width register permute; its
//! single-vector kernels and encode tile delegate to the AVX2
//! implementations, which is sound because `Avx512` only resolves when
//! both `avx512f` and `avx2` pass the runtime probe.

use crate::quant::params::{ParamBank, Variant};
use crate::quant::pipeline::RotorImpl;
use crate::quant::scalar::ScalarQuantizer;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;

/// The `[engine] kernel_backend` / `--kernel` knob: which stage-1
/// kernel implementation to run.
///
/// `Auto` (the default) picks the best backend the host supports at
/// runtime; `Scalar` forces the reference implementation (always
/// available, the property-test oracle); `Avx2`/`Neon` request a
/// specific SIMD backend and quietly fall back to scalar when the host
/// cannot run it (config loading rejects that combination up front via
/// [`KernelBackend::validate`], so a silent fallback only happens for
/// directly-constructed `Stage1Config`s).
///
/// The `ISOQUANT_KERNEL` environment variable overrides the default for
/// every `Stage1Config::new` in the process — this is how the CI matrix
/// forces `scalar` and `auto` over the whole test suite.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// the retained scalar reference (bit-exact oracle)
    Scalar,
    /// best backend the host supports (AVX2 on x86_64, NEON on aarch64,
    /// else scalar)
    #[default]
    Auto,
    /// AVX2 (x86_64, runtime-detected)
    Avx2,
    /// AVX-512 (x86_64, runtime-detected; requires `avx512f` + `avx2`)
    Avx512,
    /// NEON (aarch64, architecturally guaranteed)
    Neon,
}

/// What [`KernelBackend::resolve`] actually selected for this host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolved {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl Resolved {
    pub fn name(self) -> &'static str {
        match self {
            Resolved::Scalar => "scalar",
            Resolved::Avx2 => "avx2",
            Resolved::Avx512 => "avx512",
            Resolved::Neon => "neon",
        }
    }
}

impl KernelBackend {
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Auto => "auto",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
            KernelBackend::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s {
            "scalar" => Some(KernelBackend::Scalar),
            "auto" => Some(KernelBackend::Auto),
            "avx2" => Some(KernelBackend::Avx2),
            "avx512" => Some(KernelBackend::Avx512),
            "neon" => Some(KernelBackend::Neon),
            _ => None,
        }
    }

    /// Process-wide default: `ISOQUANT_KERNEL` if set (and valid), else
    /// `Auto`.  Cached after the first read.  An unparseable value is
    /// loudly ignored (warned once) rather than silently treated as
    /// `Auto` — a CI leg that believes it forced `scalar` must not
    /// quietly run SIMD because of a typo.
    pub fn from_env_default() -> KernelBackend {
        static CACHE: std::sync::OnceLock<KernelBackend> = std::sync::OnceLock::new();
        *CACHE.get_or_init(|| match std::env::var("ISOQUANT_KERNEL") {
            Err(_) => KernelBackend::Auto,
            Ok(s) => match KernelBackend::parse(&s) {
                Some(b) => b,
                None => {
                    eprintln!(
                        "isoquant: ignoring invalid ISOQUANT_KERNEL={s:?} \
                         (expected scalar|auto|avx2|neon|avx512); using auto"
                    );
                    KernelBackend::Auto
                }
            },
        })
    }

    /// Pick the implementation this host will actually run.
    #[allow(unreachable_code)]
    pub fn resolve(self) -> Resolved {
        match self {
            KernelBackend::Scalar => Resolved::Scalar,
            KernelBackend::Auto => host_best(),
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if std::arch::is_x86_feature_detected!("avx2") {
                        return Resolved::Avx2;
                    }
                }
                Resolved::Scalar
            }
            KernelBackend::Avx512 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!("avx2")
                    {
                        return Resolved::Avx512;
                    }
                }
                Resolved::Scalar
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    return Resolved::Neon;
                }
                Resolved::Scalar
            }
        }
    }

    /// Reject an explicitly-requested backend the host cannot run
    /// (config-loading front door; `resolve` itself falls back quietly).
    pub fn validate(self) -> Result<(), String> {
        match self {
            KernelBackend::Avx2 if self.resolve() != Resolved::Avx2 => Err(
                "kernel_backend = \"avx2\" requested but this host has no AVX2".to_string(),
            ),
            KernelBackend::Avx512 if self.resolve() != Resolved::Avx512 => Err(
                "kernel_backend = \"avx512\" requested but this host has no AVX-512".to_string(),
            ),
            KernelBackend::Neon if self.resolve() != Resolved::Neon => Err(
                "kernel_backend = \"neon\" requested but this host is not aarch64".to_string(),
            ),
            _ => Ok(()),
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Best backend the running CPU supports.
#[allow(unreachable_code)]
fn host_best() -> Resolved {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx2")
        {
            return Resolved::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return Resolved::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Resolved::Neon;
    }
    Resolved::Scalar
}

/// Structure-of-arrays copy of a rotation bank: component `c` of block
/// `b`'s quaternion at `c_arr[b]`, so 8 (or 4) consecutive blocks load
/// as one register per component.  Built once at `Stage1` construction;
/// empty for variants without a SIMD path.
#[derive(Clone, Debug, Default)]
pub struct SoaBank {
    /// left quaternion components (IsoFull / IsoFast)
    pub lw: Vec<f32>,
    pub lx: Vec<f32>,
    pub ly: Vec<f32>,
    pub lz: Vec<f32>,
    /// right quaternion components (IsoFull)
    pub rw: Vec<f32>,
    pub rx: Vec<f32>,
    pub ry: Vec<f32>,
    pub rz: Vec<f32>,
    /// planar cos/sin per pair (Planar2D)
    pub cs: Vec<f32>,
    pub sn: Vec<f32>,
    /// rotor components per 3D block (Rotor3D under OddIntermediate)
    pub rs: Vec<f32>,
    pub r12: Vec<f32>,
    pub r13: Vec<f32>,
    pub r23: Vec<f32>,
}

impl SoaBank {
    fn build(bank: &ParamBank, variant: Variant, rotor_odd: bool) -> SoaBank {
        let mut soa = SoaBank::default();
        match variant {
            Variant::IsoFull => {
                deinterleave(&bank.q_l, &mut soa.lw, &mut soa.lx, &mut soa.ly, &mut soa.lz);
                deinterleave(&bank.q_r, &mut soa.rw, &mut soa.rx, &mut soa.ry, &mut soa.rz);
            }
            Variant::IsoFast => {
                deinterleave(&bank.q_l, &mut soa.lw, &mut soa.lx, &mut soa.ly, &mut soa.lz);
            }
            Variant::Planar2D => {
                soa.cs = bank.cos_sin.iter().map(|&(c, _)| c).collect();
                soa.sn = bank.cos_sin.iter().map(|&(_, s)| s).collect();
            }
            Variant::Rotor3D if rotor_odd => {
                // same derivation as Stage1's precomputed rotors, so the
                // SIMD path sees bit-identical components
                for &q in &bank.q_l {
                    let r = crate::math::rotor3::Rotor::from_quaternion(q);
                    soa.rs.push(r.s);
                    soa.r12.push(r.b12);
                    soa.r13.push(r.b13);
                    soa.r23.push(r.b23);
                }
            }
            _ => {}
        }
        soa
    }
}

fn deinterleave(qs: &[[f32; 4]], w: &mut Vec<f32>, x: &mut Vec<f32>, y: &mut Vec<f32>, z: &mut Vec<f32>) {
    for q in qs {
        w.push(q[0]);
        x.push(q[1]);
        y.push(q[2]);
        z.push(q[3]);
    }
}

/// The per-`Stage1` kernel dispatch state: the resolved backend plus
/// the SoA parameter copy the SIMD paths read.
#[derive(Clone, Debug)]
pub struct KernelState {
    pub resolved: Resolved,
    soa: SoaBank,
    /// F16C available (x86_64) — gates the in-register f16 store tiles
    pub has_f16c: bool,
    /// Rotor3D is running the OddIntermediate rotor implementation, the
    /// only rotor form with a SIMD path (Multivector stays scalar by
    /// design — it models the baseline's 8-component expansion cost)
    pub rotor_odd: bool,
}

impl KernelState {
    pub fn build(
        requested: KernelBackend,
        bank: &ParamBank,
        variant: Variant,
        rotor_impl: RotorImpl,
    ) -> KernelState {
        let resolved = requested.resolve();
        let rotor_odd = variant == Variant::Rotor3D && rotor_impl == RotorImpl::OddIntermediate;
        let soa = if resolved == Resolved::Scalar {
            SoaBank::default()
        } else {
            SoaBank::build(bank, variant, rotor_odd)
        };
        #[cfg(target_arch = "x86_64")]
        let has_f16c = std::arch::is_x86_feature_detected!("f16c");
        #[cfg(not(target_arch = "x86_64"))]
        let has_f16c = false;
        KernelState {
            resolved,
            soa,
            has_f16c,
            rotor_odd,
        }
    }
}

// ----------------------------------------------------------------------
// pipeline entry points
//
// Each returns the number of leading *codes* it produced/consumed (a
// multiple of the variant's block size); the caller finishes the
// remaining blocks — ragged tails and sub-tile remainders — with the
// scalar reference.  A return of 0 means "no SIMD path for this
// (backend, variant)" and the caller runs fully scalar.
// ----------------------------------------------------------------------

/// SIMD prefix of the rotate→quantize (encode) body of one vector.
/// `codes` must hold `n_codes` bytes.
#[allow(unused_variables)]
pub(crate) fn encode_prefix(
    ks: &KernelState,
    variant: Variant,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
) -> usize {
    match ks.resolved {
        Resolved::Scalar => 0,
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 | Resolved::Avx512 => match variant {
            // SAFETY: Resolved::Avx2/Avx512 implies
            // is_x86_feature_detected!("avx2") succeeded (see module
            // docs); bounds are asserted inside.  The single-vector
            // kernels are AVX2-width under both backends.
            Variant::IsoFull => unsafe { avx2::encode_iso(&ks.soa, q, d, x, pre, codes, true) },
            Variant::IsoFast => unsafe { avx2::encode_iso(&ks.soa, q, d, x, pre, codes, false) },
            Variant::Planar2D => unsafe { avx2::encode_planar(&ks.soa, q, d, x, pre, codes) },
            Variant::Rotor3D if ks.rotor_odd => unsafe {
                avx2::encode_rotor(&ks.soa, q, d, x, pre, codes)
            },
            _ => 0,
        },
        #[cfg(target_arch = "aarch64")]
        Resolved::Neon => match variant {
            // SAFETY: NEON is mandatory on aarch64; bounds asserted inside.
            Variant::IsoFull => unsafe { neon::encode_iso(&ks.soa, q, d, x, pre, codes, true) },
            Variant::IsoFast => unsafe { neon::encode_iso(&ks.soa, q, d, x, pre, codes, false) },
            Variant::Planar2D => unsafe { neon::encode_planar(&ks.soa, q, d, x, pre, codes) },
            Variant::Rotor3D if ks.rotor_odd => unsafe {
                neon::encode_rotor(&ks.soa, q, d, x, pre, codes)
            },
            _ => 0,
        },
        #[allow(unreachable_patterns)]
        _ => 0,
    }
}

/// SIMD prefix of the dequantize→unrotate (decode) body of one vector.
#[allow(unused_variables)]
pub(crate) fn decode_prefix(
    ks: &KernelState,
    variant: Variant,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
) -> usize {
    match ks.resolved {
        Resolved::Scalar => 0,
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 | Resolved::Avx512 => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe { avx2::decode_iso(&ks.soa, q, d, codes, post, out, true) },
            Variant::IsoFast => unsafe { avx2::decode_iso(&ks.soa, q, d, codes, post, out, false) },
            Variant::Planar2D => unsafe { avx2::decode_planar(&ks.soa, q, d, codes, post, out) },
            Variant::Rotor3D if ks.rotor_odd => unsafe {
                avx2::decode_rotor(&ks.soa, q, d, codes, post, out)
            },
            _ => 0,
        },
        #[cfg(target_arch = "aarch64")]
        Resolved::Neon => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe { neon::decode_iso(&ks.soa, q, d, codes, post, out, true) },
            Variant::IsoFast => unsafe { neon::decode_iso(&ks.soa, q, d, codes, post, out, false) },
            Variant::Planar2D => unsafe { neon::decode_planar(&ks.soa, q, d, codes, post, out) },
            Variant::Rotor3D if ks.rotor_odd => unsafe {
                neon::decode_rotor(&ks.soa, q, d, codes, post, out)
            },
            _ => 0,
        },
        #[allow(unreachable_patterns)]
        _ => 0,
    }
}

/// Vectors per block-major tile on this (backend, variant), or 0 when
/// the tile path does not apply (then the per-vector path — itself
/// SIMD where supported — is used instead).
pub(crate) fn tile_width(ks: &KernelState, variant: Variant, d: usize) -> usize {
    if d < 4 || !matches!(variant, Variant::IsoFull | Variant::IsoFast) {
        return 0;
    }
    match ks.resolved {
        Resolved::Scalar => 0,
        Resolved::Avx2 => 8,
        Resolved::Avx512 => 16,
        Resolved::Neon => 4,
    }
}

/// Block-major tile decode: `tile_width` vectors' unpacked codes in
/// `codes_tile` (row `v` at `v * n_codes`), per-vector `post` factors,
/// destination rows at `out[v * d ..]`.  Returns codes covered per
/// vector (the caller scalar-finishes each row's ragged tail).
#[allow(unused_variables)]
pub(crate) fn decode_tile_prefix(
    ks: &KernelState,
    variant: Variant,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [f32],
) -> usize {
    match ks.resolved {
        Resolved::Scalar => 0,
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe {
                avx2::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, true)
            },
            Variant::IsoFast => unsafe {
                avx2::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, false)
            },
            _ => 0,
        },
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx512 => match variant {
            // SAFETY: Resolved::Avx512 implies the avx512f probe
            // succeeded (see module docs); bounds asserted inside.
            Variant::IsoFull => unsafe {
                avx512::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, true)
            },
            Variant::IsoFast => unsafe {
                avx512::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, false)
            },
            _ => 0,
        },
        #[cfg(target_arch = "aarch64")]
        Resolved::Neon => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe {
                neon::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, true)
            },
            Variant::IsoFast => unsafe {
                neon::decode_tile_iso(&ks.soa, q, d, codes_tile, n_codes, posts, out, false)
            },
            _ => 0,
        },
        #[allow(unreachable_patterns)]
        _ => 0,
    }
}

/// [`decode_tile_prefix`] with f16 output: each reconstructed value is
/// converted in-register (round-to-nearest-even, bit-identical to
/// `util::f16::f32_to_f16_bits`) before the store transpose.  Returns 0
/// when this (backend, variant) has no f16 tile — the caller then
/// decodes f32 and converts scalar-wise, which produces the same bits.
#[allow(unused_variables)]
pub(crate) fn decode_tile_prefix_f16(
    ks: &KernelState,
    variant: Variant,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [u16],
) -> usize {
    match ks.resolved {
        Resolved::Scalar => 0,
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 if ks.has_f16c => match variant {
            // SAFETY: see `encode_prefix`; the f16c probe gates this arm.
            Variant::IsoFull => unsafe {
                avx2::decode_tile_iso_f16(&ks.soa, q, d, codes_tile, n_codes, posts, out, true)
            },
            Variant::IsoFast => unsafe {
                avx2::decode_tile_iso_f16(&ks.soa, q, d, codes_tile, n_codes, posts, out, false)
            },
            _ => 0,
        },
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx512 if ks.has_f16c => match variant {
            // SAFETY: avx512f + f16c probes both succeeded.
            Variant::IsoFull => unsafe {
                avx512::decode_tile_iso_f16(&ks.soa, q, d, codes_tile, n_codes, posts, out, true)
            },
            Variant::IsoFast => unsafe {
                avx512::decode_tile_iso_f16(&ks.soa, q, d, codes_tile, n_codes, posts, out, false)
            },
            _ => 0,
        },
        // NEON fp16 conversion intrinsics are not yet stable, so
        // aarch64 (and any x86 without F16C) takes the f32-then-convert
        // fallback in the caller.
        #[allow(unreachable_patterns)]
        _ => 0,
    }
}

/// Dispatched packed-code expansion — the SIMD lift of
/// `packing::unpack_into`, which profiles showed as a visible fraction
/// of tile decode (every gathered record unpacks its codes before the
/// vertical sandwich).  4-bit and 2-bit widths are pure radix
/// expansions, so they vectorize as byte-shuffle interleaves
/// (`punpck` on AVX2, `vzip` on NEON): split each byte into its
/// low/high halves and interleave, once for nibbles, twice for crumbs.
/// The 3-bit width (and any remainder after the SIMD prefix, which
/// always ends byte-aligned) falls back to the scalar reference.
/// Bit-exact with `packing::unpack_into` for every backend — it is
/// exact integer work, enforced by the tests below.
pub(crate) fn unpack_codes(ks: &KernelState, data: &[u8], bits: u8, n: usize, out: &mut [u8]) {
    debug_assert!(out.len() >= n);
    #[allow(unused_mut)]
    let mut done = 0usize;
    match ks.resolved {
        Resolved::Scalar => {}
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 | Resolved::Avx512 => match bits {
            // SAFETY: Resolved::Avx2/Avx512 implies the avx2 runtime
            // probe succeeded (see module docs); bounds asserted inside.
            4 => done = unsafe { avx2::unpack4_prefix(data, n, out) },
            2 => done = unsafe { avx2::unpack2_prefix(data, n, out) },
            _ => {}
        },
        #[cfg(target_arch = "aarch64")]
        Resolved::Neon => match bits {
            // SAFETY: NEON is mandatory on aarch64; bounds asserted inside.
            4 => done = unsafe { neon::unpack4_prefix(data, n, out) },
            2 => done = unsafe { neon::unpack2_prefix(data, n, out) },
            _ => {}
        },
        #[allow(unreachable_patterns)]
        _ => {}
    }
    if done < n {
        // the SIMD prefix covers whole input bytes, so the scalar tail
        // starts byte-aligned
        crate::quant::packing::unpack_into(
            &data[done * bits as usize / 8..],
            bits,
            n - done,
            &mut out[done..n],
        );
    }
}

/// Block-major tile encode: `tile_width` vectors' rows at `x[v * d ..]`
/// with per-vector `pre` factors; code rows written to
/// `codes_tile[v * n_codes ..]`.  Returns codes covered per vector.
#[allow(unused_variables)]
pub(crate) fn encode_tile_prefix(
    ks: &KernelState,
    variant: Variant,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pres: &[f32],
    codes_tile: &mut [u8],
    n_codes: usize,
) -> usize {
    match ks.resolved {
        Resolved::Scalar => 0,
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx2 => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe {
                avx2::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, true)
            },
            Variant::IsoFast => unsafe {
                avx2::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, false)
            },
            _ => 0,
        },
        #[cfg(target_arch = "x86_64")]
        Resolved::Avx512 => match variant {
            // SAFETY: see `encode_prefix` (the 16-wide encode tile runs
            // as two AVX2 halves, so only the avx2 probe matters here).
            Variant::IsoFull => unsafe {
                avx512::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, true)
            },
            Variant::IsoFast => unsafe {
                avx512::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, false)
            },
            _ => 0,
        },
        #[cfg(target_arch = "aarch64")]
        Resolved::Neon => match variant {
            // SAFETY: see `encode_prefix`.
            Variant::IsoFull => unsafe {
                neon::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, true)
            },
            Variant::IsoFast => unsafe {
                neon::encode_tile_iso(&ks.soa, q, d, x, pres, codes_tile, n_codes, false)
            },
            _ => 0,
        },
        #[allow(unreachable_patterns)]
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_names() {
        for b in [
            KernelBackend::Scalar,
            KernelBackend::Auto,
            KernelBackend::Avx2,
            KernelBackend::Avx512,
            KernelBackend::Neon,
        ] {
            assert_eq!(KernelBackend::parse(b.name()), Some(b));
        }
        assert_eq!(KernelBackend::parse("sse9"), None);
    }

    #[test]
    fn scalar_always_resolves_scalar() {
        assert_eq!(KernelBackend::Scalar.resolve(), Resolved::Scalar);
        assert!(KernelBackend::Scalar.validate().is_ok());
        assert!(KernelBackend::Auto.validate().is_ok());
    }

    #[test]
    fn auto_resolves_to_something_runnable() {
        // whatever auto picks must be a backend this host can execute —
        // smoke-tested by building a Stage1 and running the suite under
        // it (see tests/kernel_equivalence.rs)
        let r = KernelBackend::Auto.resolve();
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert_eq!(r, Resolved::Scalar);
        let _ = r;
    }

    #[test]
    fn unpack_codes_bit_exact_with_scalar_reference() {
        use crate::quant::packing;
        use crate::util::prng::Rng;
        let bank = ParamBank::random(Variant::IsoFull, 64, 1);
        let mut rng = Rng::new(0x0DDC);
        for backend in [KernelBackend::Scalar, KernelBackend::Auto] {
            let ks = KernelState::build(backend, &bank, Variant::IsoFull, RotorImpl::Multivector);
            for bits in [2u8, 3, 4] {
                for n in [0usize, 1, 7, 31, 32, 33, 63, 64, 65, 128, 257, 1000] {
                    let codes: Vec<u8> =
                        (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
                    let mut packed = Vec::new();
                    packing::pack(&codes, bits, &mut packed);
                    let mut want = vec![0u8; n];
                    packing::unpack_into(&packed, bits, n, &mut want);
                    // sentinel beyond n must survive
                    let mut got = vec![0xEEu8; n + 3];
                    unpack_codes(&ks, &packed, bits, n, &mut got);
                    assert_eq!(&got[..n], &want[..], "{backend:?} bits={bits} n={n}");
                    assert_eq!(&got[n..], &[0xEE; 3], "{backend:?} bits={bits} n={n} overran");
                }
            }
        }
    }

    #[test]
    fn soa_bank_shapes() {
        let bank = ParamBank::random(Variant::IsoFull, 128, 1);
        let soa = SoaBank::build(&bank, Variant::IsoFull, false);
        assert_eq!(soa.lw.len(), 32);
        assert_eq!(soa.rz.len(), 32);
        for (b, q) in bank.q_l.iter().enumerate() {
            assert_eq!(soa.lw[b], q[0]);
            assert_eq!(soa.lx[b], q[1]);
            assert_eq!(soa.ly[b], q[2]);
            assert_eq!(soa.lz[b], q[3]);
        }
        let p = ParamBank::random(Variant::Planar2D, 64, 2);
        let soa = SoaBank::build(&p, Variant::Planar2D, false);
        assert_eq!(soa.cs.len(), 32);
        assert_eq!(soa.cs[3], p.cos_sin[3].0);
        assert_eq!(soa.sn[3], p.cos_sin[3].1);
    }
}
