//! NEON stage-1 kernels (aarch64) — the 4-lane mirror of `avx2.rs`.
//!
//! NEON's structured loads do the SoA transposes in hardware:
//! `vld4q_f32`/`vst4q_f32` deinterleave/reinterleave four 4D blocks in
//! one instruction, and `vld2q_f32`/`vst2q_f32` do the same for planar
//! pairs.  The ≤16-entry level table lives in a `vqtbl4q_u8` register
//! quartet (the paper's "codebook fits a shuffle register" claim).
//!
//! NEON is architecturally mandatory on aarch64, so these functions
//! carry no `#[target_feature]`; they are still kept `unsafe` and
//! behind the same dispatch boundary as AVX2 for symmetry, with all
//! accesses on ranges proven in bounds by the leading `assert!`s.
//! The bit-exactness rules from the `kernels` module docs apply
//! unchanged: exact mul/add/sub (no `vfmaq`), scalar operation order,
//! rank-count encode, table-select decode.

#![allow(clippy::too_many_arguments)]

use std::arch::aarch64::*;

use super::SoaBank;
use crate::quant::scalar::ScalarQuantizer;

/// 4 independent quaternions, one per lane, in SoA registers.
#[derive(Clone, Copy)]
struct Q4 {
    w: float32x4_t,
    x: float32x4_t,
    y: float32x4_t,
    z: float32x4_t,
}

/// Vertical Hamilton product with the exact operation order of
/// `math::quaternion::hamilton`.
#[inline(always)]
unsafe fn hamilton4(a: Q4, b: Q4) -> Q4 {
    Q4 {
        w: vsubq_f32(
            vsubq_f32(
                vsubq_f32(vmulq_f32(a.w, b.w), vmulq_f32(a.x, b.x)),
                vmulq_f32(a.y, b.y),
            ),
            vmulq_f32(a.z, b.z),
        ),
        x: vsubq_f32(
            vaddq_f32(
                vaddq_f32(vmulq_f32(a.w, b.x), vmulq_f32(a.x, b.w)),
                vmulq_f32(a.y, b.z),
            ),
            vmulq_f32(a.z, b.y),
        ),
        y: vaddq_f32(
            vaddq_f32(
                vsubq_f32(vmulq_f32(a.w, b.y), vmulq_f32(a.x, b.z)),
                vmulq_f32(a.y, b.w),
            ),
            vmulq_f32(a.z, b.x),
        ),
        z: vaddq_f32(
            vsubq_f32(
                vaddq_f32(vmulq_f32(a.w, b.z), vmulq_f32(a.x, b.y)),
                vmulq_f32(a.y, b.x),
            ),
            vmulq_f32(a.z, b.w),
        ),
    }
}

/// `encode1` as a rank count over the ascending boundary array.
#[inline(always)]
unsafe fn encode_cmp4(v: float32x4_t, bounds: &[f32; 15], n_bounds: usize) -> uint32x4_t {
    let mut acc = vdupq_n_u32(0);
    for &b in bounds.iter().take(n_bounds) {
        let m = vcgtq_f32(v, vdupq_n_f32(b)); // all-ones where v > b
        acc = vsubq_u32(acc, m);
    }
    acc
}

/// The 16-entry level table as a `vqtbl4q` register quartet.
#[inline(always)]
unsafe fn level_table(levels: &[f32; 16]) -> uint8x16x4_t {
    let p = levels.as_ptr() as *const u8;
    uint8x16x4_t(
        vld1q_u8(p),
        vld1q_u8(p.add(16)),
        vld1q_u8(p.add(32)),
        vld1q_u8(p.add(48)),
    )
}

/// `decode1` as a byte-table select: lane index i (0..16) becomes the
/// four byte indices 4i..4i+3 of the f32 level.
#[inline(always)]
unsafe fn lookup16_4(table: uint8x16x4_t, idx: uint32x4_t) -> float32x4_t {
    let base = vshlq_n_u32::<2>(idx);
    let bytes = vaddq_u32(
        vmulq_u32(base, vdupq_n_u32(0x0101_0101)),
        vdupq_n_u32(0x0302_0100),
    );
    vreinterpretq_f32_u8(vqtbl4q_u8(table, vreinterpretq_u8_u32(bytes)))
}

/// Split packed code dwords (one block/vector per lane) into four index
/// registers.
#[inline(always)]
unsafe fn unpack_code_dwords4(
    dw: uint32x4_t,
) -> (uint32x4_t, uint32x4_t, uint32x4_t, uint32x4_t) {
    let m = vdupq_n_u32(0xFF);
    (
        vandq_u32(dw, m),
        vandq_u32(vshrq_n_u32::<8>(dw), m),
        vandq_u32(vshrq_n_u32::<16>(dw), m),
        vshrq_n_u32::<24>(dw),
    )
}

#[inline(always)]
unsafe fn pack_code_dwords4(
    c0: uint32x4_t,
    c1: uint32x4_t,
    c2: uint32x4_t,
    c3: uint32x4_t,
) -> uint32x4_t {
    vorrq_u32(
        vorrq_u32(c0, vshlq_n_u32::<8>(c1)),
        vorrq_u32(vshlq_n_u32::<16>(c2), vshlq_n_u32::<24>(c3)),
    )
}

/// 4×4 f32 transpose (involutive): rows in, columns out.
#[inline(always)]
unsafe fn transpose4(
    a: float32x4_t,
    b: float32x4_t,
    c: float32x4_t,
    d: float32x4_t,
) -> Q4 {
    let t0 = vtrn1q_f32(a, b); // [a0 b0 a2 b2]
    let t1 = vtrn2q_f32(a, b); // [a1 b1 a3 b3]
    let t2 = vtrn1q_f32(c, d);
    let t3 = vtrn2q_f32(c, d);
    Q4 {
        w: vreinterpretq_f32_f64(vtrn1q_f64(
            vreinterpretq_f64_f32(t0),
            vreinterpretq_f64_f32(t2),
        )),
        x: vreinterpretq_f32_f64(vtrn1q_f64(
            vreinterpretq_f64_f32(t1),
            vreinterpretq_f64_f32(t3),
        )),
        y: vreinterpretq_f32_f64(vtrn2q_f64(
            vreinterpretq_f64_f32(t0),
            vreinterpretq_f64_f32(t2),
        )),
        z: vreinterpretq_f32_f64(vtrn2q_f64(
            vreinterpretq_f64_f32(t1),
            vreinterpretq_f64_f32(t3),
        )),
    }
}

/// Broadcast quaternion `b`, conjugated when `conj`.
#[inline(always)]
unsafe fn splat_quat4(w: &[f32], x: &[f32], y: &[f32], z: &[f32], b: usize, conj: bool) -> Q4 {
    let s = if conj { -1.0f32 } else { 1.0 };
    Q4 {
        w: vdupq_n_f32(w[b]),
        x: vdupq_n_f32(s * x[b]),
        y: vdupq_n_f32(s * y[b]),
        z: vdupq_n_f32(s * z[b]),
    }
}

/// Load 4 consecutive blocks' quaternion components from the SoA bank.
#[inline(always)]
unsafe fn load_quats4(w: &[f32], x: &[f32], y: &[f32], z: &[f32], b0: usize, conj: bool) -> Q4 {
    let q = Q4 {
        w: vld1q_f32(w.as_ptr().add(b0)),
        x: vld1q_f32(x.as_ptr().add(b0)),
        y: vld1q_f32(y.as_ptr().add(b0)),
        z: vld1q_f32(z.as_ptr().add(b0)),
    };
    if conj {
        Q4 {
            w: q.w,
            x: vnegq_f32(q.x),
            y: vnegq_f32(q.y),
            z: vnegq_f32(q.z),
        }
    } else {
        q
    }
}

// ---------------------------------------------------------------------
// single-vector kernels (4 blocks per iteration)
// ---------------------------------------------------------------------

pub(crate) unsafe fn encode_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
    use_right: bool,
) -> usize {
    let full = d / 4;
    let nsimd = full - full % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 4);
    assert!(codes.len() >= nsimd * 4);
    assert!(soa.lw.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = vdupq_n_f32(pre);
    for b0 in (0..nsimd).step_by(4) {
        let raw = vld4q_f32(x.as_ptr().add(b0 * 4)); // hw deinterleave
        let v = Q4 {
            w: vmulq_f32(raw.0, prev),
            x: vmulq_f32(raw.1, prev),
            y: vmulq_f32(raw.2, prev),
            z: vmulq_f32(raw.3, prev),
        };
        let l = load_quats4(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b0, false);
        let mut y = hamilton4(l, v);
        if use_right {
            let r = load_quats4(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b0, true);
            y = hamilton4(y, r);
        }
        let packed = pack_code_dwords4(
            encode_cmp4(y.w, bounds, nb),
            encode_cmp4(y.x, bounds, nb),
            encode_cmp4(y.y, bounds, nb),
            encode_cmp4(y.z, bounds, nb),
        );
        vst1q_u8(codes.as_mut_ptr().add(b0 * 4), vreinterpretq_u8_u32(packed));
    }
    nsimd * 4
}

pub(crate) unsafe fn decode_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
    use_right: bool,
) -> usize {
    let full = d / 4;
    let nsimd = full - full % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 4);
    assert!(out.len() >= nsimd * 4);
    assert!(soa.lw.len() >= nsimd);
    let table = level_table(q.levels_padded());
    let postv = vdupq_n_f32(post);
    for b0 in (0..nsimd).step_by(4) {
        let raw = vld1q_u8(codes.as_ptr().add(b0 * 4));
        let (iw, ix, iy, iz) = unpack_code_dwords4(vreinterpretq_u32_u8(raw));
        let yq = Q4 {
            w: lookup16_4(table, iw),
            x: lookup16_4(table, ix),
            y: lookup16_4(table, iy),
            z: lookup16_4(table, iz),
        };
        let lc = load_quats4(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b0, true);
        let mut r = hamilton4(lc, yq);
        if use_right {
            let rp = load_quats4(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b0, false);
            r = hamilton4(r, rp);
        }
        let o = float32x4x4_t(
            vmulq_f32(r.w, postv),
            vmulq_f32(r.x, postv),
            vmulq_f32(r.y, postv),
            vmulq_f32(r.z, postv),
        );
        vst4q_f32(out.as_mut_ptr().add(b0 * 4), o); // hw reinterleave
    }
    nsimd * 4
}

pub(crate) unsafe fn encode_planar(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
) -> usize {
    let full = d / 2;
    let nsimd = full - full % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 2);
    assert!(codes.len() >= nsimd * 2);
    assert!(soa.cs.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = vdupq_n_f32(pre);
    for p0 in (0..nsimd).step_by(4) {
        let raw = vld2q_f32(x.as_ptr().add(p0 * 2)); // (evens, odds)
        let u0 = vmulq_f32(raw.0, prev);
        let u1 = vmulq_f32(raw.1, prev);
        let c = vld1q_f32(soa.cs.as_ptr().add(p0));
        let s = vld1q_f32(soa.sn.as_ptr().add(p0));
        let y0 = vsubq_f32(vmulq_f32(c, u0), vmulq_f32(s, u1)); // c*u0 - s*u1
        let y1 = vaddq_f32(vmulq_f32(s, u0), vmulq_f32(c, u1)); // s*u0 + c*u1
        let packed = vorrq_u32(
            encode_cmp4(y0, bounds, nb),
            vshlq_n_u32::<8>(encode_cmp4(y1, bounds, nb)),
        );
        let mut buf = [0u32; 4];
        vst1q_u32(buf.as_mut_ptr(), packed);
        for (k, &pk) in buf.iter().enumerate() {
            codes[(p0 + k) * 2] = pk as u8;
            codes[(p0 + k) * 2 + 1] = (pk >> 8) as u8;
        }
    }
    nsimd * 2
}

pub(crate) unsafe fn decode_planar(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
) -> usize {
    let full = d / 2;
    let nsimd = full - full % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 2);
    assert!(out.len() >= nsimd * 2);
    assert!(soa.cs.len() >= nsimd);
    let table = level_table(q.levels_padded());
    let postv = vdupq_n_f32(post);
    for p0 in (0..nsimd).step_by(4) {
        // 4 pairs = 8 code bytes; widen to one dword per pair
        let b8 = vld1_u8(codes.as_ptr().add(p0 * 2));
        let wide = vmovl_u16(vreinterpret_u16_u8(b8));
        let i0 = vandq_u32(wide, vdupq_n_u32(0xFF));
        let i1 = vshrq_n_u32::<8>(wide);
        let y0 = lookup16_4(table, i0);
        let y1 = lookup16_4(table, i1);
        let c = vld1q_f32(soa.cs.as_ptr().add(p0));
        let s = vld1q_f32(soa.sn.as_ptr().add(p0));
        // (c*y0 + s*y1) * post ; (-s*y0 + c*y1) * post
        let o0 = vmulq_f32(vaddq_f32(vmulq_f32(c, y0), vmulq_f32(s, y1)), postv);
        let o1 = vmulq_f32(
            vaddq_f32(vmulq_f32(vnegq_f32(s), y0), vmulq_f32(c, y1)),
            postv,
        );
        vst2q_f32(out.as_mut_ptr().add(p0 * 2), float32x4x2_t(o0, o1));
    }
    nsimd * 2
}

// ---------------------------------------------------------------------
// block-major tile kernels (4 vectors per tile)
// ---------------------------------------------------------------------

pub(crate) unsafe fn decode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [f32],
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(posts.len(), 4);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 4 * n_codes);
    assert!(out.len() >= 3 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let table = level_table(q.levels_padded());
    let postv = vld1q_f32(posts.as_ptr());
    let outp = out.as_mut_ptr();
    for b in 0..full {
        let col = 4 * b;
        // lane v = vector v's four packed code bytes for block b
        let mut rows = [0u32; 4];
        for (v, r) in rows.iter_mut().enumerate() {
            let off = v * n_codes + col;
            *r = u32::from_le_bytes([
                codes_tile[off],
                codes_tile[off + 1],
                codes_tile[off + 2],
                codes_tile[off + 3],
            ]);
        }
        let (iw, ix, iy, iz) = unpack_code_dwords4(vld1q_u32(rows.as_ptr()));
        let yq = Q4 {
            w: lookup16_4(table, iw),
            x: lookup16_4(table, ix),
            y: lookup16_4(table, iy),
            z: lookup16_4(table, iz),
        };
        let lc = splat_quat4(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, true);
        let mut r = hamilton4(lc, yq);
        if use_right {
            let rp = splat_quat4(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, false);
            r = hamilton4(r, rp);
        }
        let o = Q4 {
            w: vmulq_f32(r.w, postv),
            x: vmulq_f32(r.x, postv),
            y: vmulq_f32(r.y, postv),
            z: vmulq_f32(r.z, postv),
        };
        // columns -> per-vector rows, then scatter
        let t = transpose4(o.w, o.x, o.y, o.z);
        vst1q_f32(outp.add(col), t.w);
        vst1q_f32(outp.add(d + col), t.x);
        vst1q_f32(outp.add(2 * d + col), t.y);
        vst1q_f32(outp.add(3 * d + col), t.z);
    }
    full * 4
}

pub(crate) unsafe fn encode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pres: &[f32],
    codes_tile: &mut [u8],
    n_codes: usize,
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(pres.len(), 4);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 4 * n_codes);
    assert!(x.len() >= 3 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = vld1q_f32(pres.as_ptr());
    let xp = x.as_ptr();
    for b in 0..full {
        let col = 4 * b;
        let raw = transpose4(
            vld1q_f32(xp.add(col)),
            vld1q_f32(xp.add(d + col)),
            vld1q_f32(xp.add(2 * d + col)),
            vld1q_f32(xp.add(3 * d + col)),
        );
        let v = Q4 {
            w: vmulq_f32(raw.w, prev),
            x: vmulq_f32(raw.x, prev),
            y: vmulq_f32(raw.y, prev),
            z: vmulq_f32(raw.z, prev),
        };
        let l = splat_quat4(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, false);
        let mut y = hamilton4(l, v);
        if use_right {
            let r = splat_quat4(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, true);
            y = hamilton4(y, r);
        }
        let packed = pack_code_dwords4(
            encode_cmp4(y.w, bounds, nb),
            encode_cmp4(y.x, bounds, nb),
            encode_cmp4(y.y, bounds, nb),
            encode_cmp4(y.z, bounds, nb),
        );
        let mut buf = [0u32; 4];
        vst1q_u32(buf.as_mut_ptr(), packed);
        for (v_i, &dword) in buf.iter().enumerate() {
            let off = v_i * n_codes + col;
            codes_tile[off..off + 4].copy_from_slice(&dword.to_le_bytes());
        }
    }
    full * 4
}

// ---------------------------------------------------------------------
// packed-code expansion (the SIMD unpack_into: 4-bit nibbles and 2-bit
// crumbs are radix expansions, vectorized as `vzip` byte interleaves)
// ---------------------------------------------------------------------

/// Expand the leading `n / 32 * 32` 4-bit codes of `data` into one code
/// byte each: split each byte into low/high nibbles and `vzip` them,
/// reproducing the scalar order exactly (code 2i = byte i & 0xF,
/// code 2i+1 = byte i >> 4).  Returns codes covered (a multiple of 32,
/// so the scalar tail starts byte-aligned).
pub(super) unsafe fn unpack4_prefix(data: &[u8], n: usize, out: &mut [u8]) -> usize {
    let chunks = n / 32;
    assert!(data.len() >= chunks * 16);
    assert!(out.len() >= chunks * 32);
    for c in 0..chunks {
        let src = vld1q_u8(data.as_ptr().add(c * 16));
        let lo = vandq_u8(src, vdupq_n_u8(0x0F));
        let hi = vshrq_n_u8::<4>(src); // byte shift: no cross-byte leak
        vst1q_u8(out.as_mut_ptr().add(c * 32), vzip1q_u8(lo, hi));
        vst1q_u8(out.as_mut_ptr().add(c * 32 + 16), vzip2q_u8(lo, hi));
    }
    chunks * 32
}

/// Expand the leading `n / 64 * 64` 2-bit codes of `data`: the nibble
/// split above applied twice (byte → nibbles → crumbs), order-stable
/// at every stage.  Returns codes covered (a multiple of 64).
pub(super) unsafe fn unpack2_prefix(data: &[u8], n: usize, out: &mut [u8]) -> usize {
    let chunks = n / 64;
    assert!(data.len() >= chunks * 16);
    assert!(out.len() >= chunks * 64);
    let m2 = vdupq_n_u8(0x03);
    for c in 0..chunks {
        let src = vld1q_u8(data.as_ptr().add(c * 16));
        let nib_lo = vandq_u8(src, vdupq_n_u8(0x0F));
        let nib_hi = vshrq_n_u8::<4>(src);
        // na covers input bytes 0..8 (codes 0..32), nb bytes 8..16
        let na = vzip1q_u8(nib_lo, nib_hi);
        let nb = vzip2q_u8(nib_lo, nib_hi);
        for (half, v) in [na, nb].into_iter().enumerate() {
            let cl = vandq_u8(v, m2);
            let ch = vandq_u8(vshrq_n_u8::<2>(v), m2);
            let dst = out.as_mut_ptr().add(c * 64 + half * 32);
            vst1q_u8(dst, vzip1q_u8(cl, ch));
            vst1q_u8(dst.add(16), vzip2q_u8(cl, ch));
        }
    }
    chunks * 64
}

// ---------------------------------------------------------------------
// Rotor3D baseline kernels (OddIntermediate only): 4 3-blocks per
// iteration — `vld3q_f32`/`vst3q_f32` do the 3-wide SoA (de)interleave
// in hardware, so the "3 blocks in 4 lanes" padding problem disappears.
// ---------------------------------------------------------------------

/// Vertical `Rotor::apply` with the exact left-to-right association of
/// the scalar odd-intermediate sandwich (`math::rotor3::Rotor::apply`).
/// For `apply_inv`, pass the bivector components negated (`reverse()`
/// is an exact sign flip).
#[inline(always)]
unsafe fn rotor_apply4(
    s: float32x4_t,
    b12: float32x4_t,
    b13: float32x4_t,
    b23: float32x4_t,
    v1: float32x4_t,
    v2: float32x4_t,
    v3: float32x4_t,
) -> (float32x4_t, float32x4_t, float32x4_t) {
    let o1 = vaddq_f32(
        vaddq_f32(vmulq_f32(s, v1), vmulq_f32(b12, v2)),
        vmulq_f32(b13, v3),
    );
    let o2 = vaddq_f32(
        vsubq_f32(vmulq_f32(s, v2), vmulq_f32(b12, v1)),
        vmulq_f32(b23, v3),
    );
    let o3 = vsubq_f32(
        vsubq_f32(vmulq_f32(s, v3), vmulq_f32(b13, v1)),
        vmulq_f32(b23, v2),
    );
    let o123 = vaddq_f32(
        vsubq_f32(vmulq_f32(b23, v1), vmulq_f32(b13, v2)),
        vmulq_f32(b12, v3),
    );
    let r1 = vaddq_f32(
        vaddq_f32(
            vaddq_f32(vmulq_f32(o1, s), vmulq_f32(o2, b12)),
            vmulq_f32(o3, b13),
        ),
        vmulq_f32(o123, b23),
    );
    let r2 = vaddq_f32(
        vsubq_f32(
            vsubq_f32(vmulq_f32(o2, s), vmulq_f32(o1, b12)),
            vmulq_f32(o123, b13),
        ),
        vmulq_f32(o3, b23),
    );
    let r3 = vsubq_f32(
        vsubq_f32(
            vaddq_f32(vmulq_f32(o3, s), vmulq_f32(o123, b12)),
            vmulq_f32(o1, b13),
        ),
        vmulq_f32(o2, b23),
    );
    (r1, r2, r3)
}

/// Rotor3D rotate→quantize of the leading `4⌊(d/3)/4⌋` 3-blocks of one
/// vector; returns codes written.  The `d % 3` tail is always scalar
/// (it uses the separate k=2 tail quantizer).
pub(crate) unsafe fn encode_rotor(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
) -> usize {
    let nfull = d / 3;
    let nsimd = nfull - nfull % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 3);
    assert!(codes.len() >= nsimd * 3);
    assert!(soa.rs.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = vdupq_n_f32(pre);
    for b0 in (0..nsimd).step_by(4) {
        let raw = vld3q_f32(x.as_ptr().add(b0 * 3)); // hw 3-wide deinterleave
        let v1 = vmulq_f32(raw.0, prev);
        let v2 = vmulq_f32(raw.1, prev);
        let v3 = vmulq_f32(raw.2, prev);
        let s = vld1q_f32(soa.rs.as_ptr().add(b0));
        let b12 = vld1q_f32(soa.r12.as_ptr().add(b0));
        let b13 = vld1q_f32(soa.r13.as_ptr().add(b0));
        let b23 = vld1q_f32(soa.r23.as_ptr().add(b0));
        let (r1, r2, r3) = rotor_apply4(s, b12, b13, b23, v1, v2, v3);
        let mut c1 = [0u32; 4];
        let mut c2 = [0u32; 4];
        let mut c3 = [0u32; 4];
        vst1q_u32(c1.as_mut_ptr(), encode_cmp4(r1, bounds, nb));
        vst1q_u32(c2.as_mut_ptr(), encode_cmp4(r2, bounds, nb));
        vst1q_u32(c3.as_mut_ptr(), encode_cmp4(r3, bounds, nb));
        for k in 0..4 {
            let p = (b0 + k) * 3;
            codes[p] = c1[k] as u8;
            codes[p + 1] = c2[k] as u8;
            codes[p + 2] = c3[k] as u8;
        }
    }
    nsimd * 3
}

/// Rotor3D dequantize→unrotate of the leading `4⌊(d/3)/4⌋` 3-blocks;
/// returns codes consumed.
pub(crate) unsafe fn decode_rotor(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
) -> usize {
    let nfull = d / 3;
    let nsimd = nfull - nfull % 4;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 3);
    assert!(out.len() >= nsimd * 3);
    assert!(soa.rs.len() >= nsimd);
    let table = level_table(q.levels_padded());
    let postv = vdupq_n_f32(post);
    for b0 in (0..nsimd).step_by(4) {
        let mut i1 = [0u32; 4];
        let mut i2 = [0u32; 4];
        let mut i3 = [0u32; 4];
        for k in 0..4 {
            let p = (b0 + k) * 3;
            i1[k] = codes[p] as u32;
            i2[k] = codes[p + 1] as u32;
            i3[k] = codes[p + 2] as u32;
        }
        let y1 = lookup16_4(table, vld1q_u32(i1.as_ptr()));
        let y2 = lookup16_4(table, vld1q_u32(i2.as_ptr()));
        let y3 = lookup16_4(table, vld1q_u32(i3.as_ptr()));
        // apply_inv = reverse().apply(): exact sign flip of the bivector
        let s = vld1q_f32(soa.rs.as_ptr().add(b0));
        let b12 = vnegq_f32(vld1q_f32(soa.r12.as_ptr().add(b0)));
        let b13 = vnegq_f32(vld1q_f32(soa.r13.as_ptr().add(b0)));
        let b23 = vnegq_f32(vld1q_f32(soa.r23.as_ptr().add(b0)));
        let (r1, r2, r3) = rotor_apply4(s, b12, b13, b23, y1, y2, y3);
        let o = float32x4x3_t(
            vmulq_f32(r1, postv),
            vmulq_f32(r2, postv),
            vmulq_f32(r3, postv),
        );
        vst3q_f32(out.as_mut_ptr().add(b0 * 3), o); // hw 3-wide reinterleave
    }
    nsimd * 3
}
