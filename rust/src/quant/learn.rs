//! Learned rotation parameters (paper §5.5 / Table 3 "learned
//! normalized" axis): derivative-free refinement of the quaternion /
//! angle banks on a calibration batch.
//!
//! The paper parameterizes unit quaternions by normalizing unconstrained
//! vectors and leaves learned-vs-random as an open question (§10 item 3).
//! We optimize directly on the manifold with a simple annealed random
//! search per block: propose a slerp step toward a random quaternion
//! (resp. an angle nudge), accept if calibration MSE improves.  Blocks
//! are independent given the input (block-diagonal transform), so each
//! block's objective is separable — this makes coordinate-wise search
//! exact rather than a heuristic.

use crate::math::quaternion::{self as quat};
use crate::quant::params::{ParamBank, Variant};
use crate::quant::pipeline::{Stage1, Stage1Config};
use crate::util::prng::Rng;

/// Options for the learner.
#[derive(Clone, Debug)]
pub struct LearnOptions {
    pub iters: usize,
    /// initial slerp step toward proposals
    pub step0: f32,
    /// multiplicative step decay per iteration
    pub decay: f32,
    pub seed: u64,
}

impl Default for LearnOptions {
    fn default() -> Self {
        LearnOptions {
            iters: 60,
            step0: 0.5,
            decay: 0.95,
            seed: 0xBEEF,
        }
    }
}

/// Per-block calibration MSE for the current bank.
fn block_mse(stage: &Stage1, data: &[f32], n: usize) -> Vec<f64> {
    let d = stage.d();
    let k = block_span(stage.cfg.variant);
    let nblocks = d.div_ceil(k);
    let mut out = vec![0.0f32; d];
    let mut acc = vec![0.0f64; nblocks];
    for r in 0..n {
        let x = &data[r * d..(r + 1) * d];
        stage.roundtrip(x, &mut out);
        for b in 0..nblocks {
            let lo = b * k;
            let hi = ((b + 1) * k).min(d);
            for i in lo..hi {
                let e = (x[i] - out[i]) as f64;
                acc[b] += e * e;
            }
        }
    }
    acc
}

fn block_span(variant: Variant) -> usize {
    match variant {
        Variant::IsoFull | Variant::IsoFast => 4,
        Variant::Planar2D => 2,
        Variant::Rotor3D => 3, // tail handled by its own angle
        Variant::Grouped8D => 8,
        Variant::Dense => usize::MAX, // not block-separable; unsupported
    }
}

/// Refine a bank on calibration data (row-major n × d).  Returns the
/// learned stage and the (before, after) calibration MSE.
pub fn learn(cfg: Stage1Config, data: &[f32], n: usize, opts: &LearnOptions) -> (Stage1, f64, f64) {
    assert_ne!(
        cfg.variant,
        Variant::Dense,
        "dense banks are not block-separable; learn() supports blockwise variants"
    );
    let d = cfg.d;
    assert_eq!(data.len(), n * d);
    let mut rng = Rng::new(opts.seed);
    let mut bank = ParamBank::random(cfg.variant, d, cfg.seed);
    let mut stage = Stage1::with_bank(cfg.clone(), bank.clone());

    let total = |per_block: &[f64]| per_block.iter().sum::<f64>() / (n * d) as f64;
    let mut cur = block_mse(&stage, data, n);
    let before = total(&cur);

    let mut step = opts.step0;
    for _ in 0..opts.iters {
        // propose one joint perturbation; accept per-block (separable)
        let mut cand = bank.clone();
        for q in cand.q_l.iter_mut() {
            *q = quat::slerp(*q, rng.haar_quaternion(), step);
        }
        for q in cand.q_r.iter_mut() {
            *q = quat::slerp(*q, rng.haar_quaternion(), step);
        }
        for t in cand.theta.iter_mut() {
            *t += (rng.gaussian() as f32) * step;
        }
        cand.refresh_cos_sin();
        let cand_stage = Stage1::with_bank(cfg.clone(), cand.clone());
        let cand_mse = block_mse(&cand_stage, data, n);

        // per-block accept: keep whichever parameters scored lower.
        // Block b of span k maps to q_l[b] (+ q_r[b]) for 4D, theta[b]
        // for 2D, q_l[b] for rotor blocks, pairs (2b, 2b+1) for 8D.
        let nblocks = cur.len();
        for b in 0..nblocks {
            if cand_mse[b] < cur[b] {
                match cfg.variant {
                    Variant::IsoFull => {
                        bank.q_l[b] = cand.q_l[b];
                        bank.q_r[b] = cand.q_r[b];
                    }
                    Variant::IsoFast => bank.q_l[b] = cand.q_l[b],
                    Variant::Planar2D => bank.theta[b] = cand.theta[b],
                    Variant::Rotor3D => {
                        if b < bank.q_l.len() {
                            bank.q_l[b] = cand.q_l[b];
                        } else if !bank.theta.is_empty() {
                            bank.theta[0] = cand.theta[0];
                        }
                    }
                    Variant::Grouped8D => {
                        bank.q_l[2 * b] = cand.q_l[2 * b];
                        bank.q_l[2 * b + 1] = cand.q_l[2 * b + 1];
                        bank.q_r[2 * b] = cand.q_r[2 * b];
                        bank.q_r[2 * b + 1] = cand.q_r[2 * b + 1];
                    }
                    Variant::Dense => unreachable!(),
                }
                cur[b] = cand_mse[b];
            }
        }
        bank.refresh_cos_sin();
        stage = Stage1::with_bank(cfg.clone(), bank.clone());
        step *= opts.decay;
    }
    let after = total(&block_mse(&stage, data, n));
    (stage, before, after)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Correlated calibration data: energy concentrated per block, the
    /// case where learned rotations should beat random ones.
    fn concentrated_data(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; n * d];
        for r in 0..n {
            for b in 0..d / 4 {
                let base = rng.gaussian() as f32;
                x[r * d + b * 4] = base;
                x[r * d + b * 4 + 1] = 0.9 * base + 0.05 * rng.gaussian() as f32;
                x[r * d + b * 4 + 2] = 0.1 * rng.gaussian() as f32;
                x[r * d + b * 4 + 3] = 0.05 * rng.gaussian() as f32;
            }
        }
        x
    }

    #[test]
    fn learning_reduces_calibration_mse() {
        let mut rng = Rng::new(1);
        let (n, d) = (128usize, 32usize);
        let data = concentrated_data(&mut rng, n, d);
        let cfg = Stage1Config::new(Variant::IsoFull, d, 2);
        let opts = LearnOptions {
            iters: 40,
            ..Default::default()
        };
        let (_stage, before, after) = learn(cfg, &data, n, &opts);
        assert!(
            after < before * 0.95,
            "learning should improve ≥5%: {before} → {after}"
        );
    }

    #[test]
    fn learned_generalizes_to_heldout() {
        let mut rng = Rng::new(2);
        let (n, d) = (128usize, 32usize);
        let train = concentrated_data(&mut rng, n, d);
        let test = concentrated_data(&mut rng, n, d);
        let cfg = Stage1Config::new(Variant::IsoFull, d, 2);
        let (learned, _, _) = learn(
            cfg.clone(),
            &train,
            n,
            &LearnOptions {
                iters: 40,
                ..Default::default()
            },
        );
        let random = Stage1::new(cfg);
        let mut out = vec![0.0f32; n * d];
        learned.roundtrip_batch(&test, &mut out, n);
        let mse_learned = crate::quant::pipeline::mse(&test, &out);
        random.roundtrip_batch(&test, &mut out, n);
        let mse_random = crate::quant::pipeline::mse(&test, &out);
        assert!(
            mse_learned < mse_random,
            "learned {mse_learned} vs random {mse_random}"
        );
    }

    #[test]
    fn learn_supports_planar_and_fast() {
        let mut rng = Rng::new(3);
        let (n, d) = (64usize, 16usize);
        let data = concentrated_data(&mut rng, n, d);
        for v in [Variant::IsoFast, Variant::Planar2D] {
            let cfg = Stage1Config::new(v, d, 2);
            let (_s, before, after) = learn(
                cfg,
                &data,
                n,
                &LearnOptions {
                    iters: 25,
                    ..Default::default()
                },
            );
            assert!(after <= before, "{v:?}: {before} → {after}");
        }
    }

    #[test]
    #[should_panic(expected = "not block-separable")]
    fn dense_rejected() {
        let data = vec![0.0f32; 64];
        learn(
            Stage1Config::new(Variant::Dense, 8, 2),
            &data,
            8,
            &LearnOptions::default(),
        );
    }
}
