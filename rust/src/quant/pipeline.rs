//! The stage-1 quantization pipeline (paper Alg. 1) — the native hot
//! path executed on the serving critical path and measured by the
//! Table-2 sweep.
//!
//! Semantics are pinned to the Pallas/HLO graphs (`python/compile/`):
//!
//! 1. ρ = ‖x‖₂, x̄ = x / max(ρ, ε)                    (eq. 3)
//! 2. blockwise rotation of x̄                        (eq. 22/25/29)
//! 3. per-coordinate quantize→dequantize of √d·x̄      (scalar Q)
//! 4. inverse blockwise rotation                      (eq. 24/27/31)
//! 5. scale by ρ
//!
//! The fused implementation folds the √d/ρ scaling into a single
//! pre-factor, keeps each block in registers from load to store, and
//! never materializes rotation matrices — the paper's closed-form
//! quaternion-sandwich claim.  [`Stage1::roundtrip`] is the
//! quantize–dequantize path benchmarked in Table 2;
//! [`Stage1::encode`]/[`Stage1::decode`] add bit-packing and are what the
//! KV cache stores.
//!
//! # Batch API (the serving hot path)
//!
//! The per-vector [`Stage1::encode`]/[`Stage1::decode`] pair allocates
//! scratch on every call and is retained as the *reference* the batch
//! path is property-tested against.  The cache and engine drive the
//! batch-first API instead:
//!
//! * [`Stage1::encode_batch`] compresses `n_vecs` row-major `d`-vectors
//!   into a [`PackedSink`] — one contiguous run of `encoded_len()`-byte
//!   records (f32 norm + byte-padded packed codes, identical bytes to
//!   per-vector [`Stage1::encode`]).  The sink's buffers persist across
//!   calls, so steady-state appends allocate nothing.
//! * [`Stage1::decode_batch_strided`] walks `n_vecs` encoded records
//!   spaced `stride` bytes apart (a KV page stores one token per
//!   `slot_bytes()` stride) and reconstructs straight into a contiguous
//!   `n_vecs × d` f32 destination — the lane-major gather layout — via a
//!   reusable [`BatchScratch`], with no intermediate per-vector `Vec`s.
//!   [`Stage1::decode_batch`] is the contiguous (`stride == encoded_len`)
//!   special case.
//!
//! Both batch directions are bit-exact with their per-vector references
//! (`rust/tests/proptest_invariants.rs` sweeps every variant × d × bits
//! combination plus ragged tails), so threading page decodes across
//! cores cannot change served results.
//!
//! # SIMD kernels
//!
//! The encode/decode bodies dispatch through [`quant::kernels`]
//! (`Stage1Config::backend`, default auto-detected): AVX2/NEON kernels
//! cover the IsoFull/IsoFast/Planar2D rotate→quantize and
//! dequantize→unrotate loops — single-vector SoA-across-blocks kernels
//! for `encode`/`decode`, and block-major multi-vector tiles inside
//! [`Stage1::encode_batch`] / [`Stage1::decode_batch_strided`].  Every
//! SIMD path is bit-exact with the scalar reference (which
//! `KernelBackend::Scalar` selects at runtime), so the backend knob can
//! never change served results — `rust/tests/kernel_equivalence.rs`
//! enforces this across the full Table-2 sweep.
//!
//! [`quant::kernels`]: crate::quant::kernels

use crate::math::quaternion::{self as quat};
use crate::math::rotor3::Rotor;
use crate::quant::kernels::{self, KernelBackend, KernelState};
use crate::quant::packing;
use crate::quant::params::{ParamBank, Variant};
use crate::quant::scalar::{QuantKind, ScalarQuantizer};
use crate::util::f16;

const EPS: f32 = 1e-12;

/// Fixed interleave used by the grouped-8D variant between its two
/// rotation stages (hierarchical cross-block mixing, paper §10).
const P8: [usize; 8] = [0, 4, 1, 5, 2, 6, 3, 7];

/// How the RotorQuant baseline realizes the Cl(3,0) sandwich.
///
/// The paper attributes part of RotorQuant's cost to "the expansion to an
/// 8-component multivector representation" (§9.3) — that is what the
/// released rotor kernel pays and what [`RotorImpl::Multivector`]
/// reproduces (the default, used by the Table-2 baseline).
/// [`RotorImpl::OddIntermediate`] is the *best-case* rotor kernel (two
/// quaternion-shaped products through the 4-component odd intermediate);
/// the ablation benches report both so the baseline-implementation and
/// method-intrinsic contributions to the speedup can be separated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotorImpl {
    Multivector,
    OddIntermediate,
}

/// Reusable destination for [`Stage1::encode_batch`]: a contiguous run
/// of encoded vectors plus the quantize scratch, all retained across
/// calls so steady-state encoding allocates nothing.
#[derive(Debug, Default)]
pub struct PackedSink {
    /// `n_vecs × encoded_len` bytes, vector `i` at `i * encoded_len`
    bytes: Vec<u8>,
    /// per-vector code-index scratch (`n_codes` entries)
    codes: Vec<u8>,
    /// block-major tile scratch: `tile × n_codes` code rows (SIMD path)
    tile_codes: Vec<u8>,
    /// per-tile-vector norms and pre-factors (SIMD path)
    rhos: Vec<f32>,
    pres: Vec<f32>,
    encoded_len: usize,
    n_vecs: usize,
}

impl PackedSink {
    pub fn new() -> PackedSink {
        PackedSink::default()
    }

    /// Number of encoded vectors from the last `encode_batch` call.
    pub fn len(&self) -> usize {
        self.n_vecs
    }

    pub fn is_empty(&self) -> bool {
        self.n_vecs == 0
    }

    /// The `i`-th encoded vector (norm + packed codes).
    pub fn encoded(&self, i: usize) -> &[u8] {
        assert!(i < self.n_vecs, "PackedSink: vector {i} of {}", self.n_vecs);
        &self.bytes[i * self.encoded_len..(i + 1) * self.encoded_len]
    }

    /// All encoded vectors as one contiguous byte run.
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes[..self.n_vecs * self.encoded_len]
    }
}

/// Reusable scratch for [`Stage1::decode_batch_strided`] — one per
/// concurrent decode strip.
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// unpacked code indices of the vector being decoded (`n_codes`)
    codes: Vec<u8>,
    /// block-major tile scratch: `tile × n_codes` code rows (SIMD path)
    tile_codes: Vec<u8>,
    /// per-tile-vector post-factors (SIMD path)
    posts: Vec<f32>,
    /// f32 staging tile for the f16-output generic fallback
    fstage: Vec<f32>,
    /// f32 staging row for f16-output ragged tails / remainder rows
    frow: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }
}

/// Full configuration of a stage-1 transform.
#[derive(Clone, Debug)]
pub struct Stage1Config {
    pub variant: Variant,
    pub d: usize,
    pub bits: u8,
    pub quant: QuantKind,
    pub seed: u64,
    pub rotor_impl: RotorImpl,
    /// which kernel implementation runs the encode/decode bodies (all
    /// backends are bit-exact; `Scalar` is the reference).  Defaults to
    /// `Auto` unless the `ISOQUANT_KERNEL` env var overrides it.
    pub backend: KernelBackend,
}

impl Stage1Config {
    pub fn new(variant: Variant, d: usize, bits: u8) -> Stage1Config {
        Stage1Config {
            variant,
            d,
            bits,
            quant: QuantKind::Lloyd,
            seed: 0x150_0541,
            rotor_impl: RotorImpl::Multivector,
            backend: KernelBackend::from_env_default(),
        }
    }

    pub fn with_rotor_impl(mut self, imp: RotorImpl) -> Stage1Config {
        self.rotor_impl = imp;
        self
    }

    pub fn with_backend(mut self, backend: KernelBackend) -> Stage1Config {
        self.backend = backend;
        self
    }

    /// Stable identity of the *byte format* this config produces: two
    /// configs with equal fingerprints encode any input to identical
    /// bytes, so their encoded records are interchangeable (the
    /// content-addressing premise of the KV prefix cache).  The kernel
    /// `backend` is deliberately excluded — every backend is bit-exact
    /// by contract (`tests/kernel_equivalence.rs`), so pages written
    /// under different backends stay shareable.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::prng::mix64;
        let mut h = 0x1505_1505_1505_1505u64;
        h = mix64(h, self.variant as u64);
        h = mix64(h, self.d as u64);
        h = mix64(h, self.bits as u64);
        h = mix64(
            h,
            match self.quant {
                QuantKind::Lloyd => 0,
                QuantKind::Uniform => 1,
            },
        );
        h = mix64(h, self.seed);
        h = mix64(
            h,
            match self.rotor_impl {
                RotorImpl::Multivector => 0,
                RotorImpl::OddIntermediate => 1,
            },
        );
        h
    }
}

/// A ready-to-run stage-1 transform: parameter bank + quantizers.
#[derive(Clone, Debug)]
pub struct Stage1 {
    pub cfg: Stage1Config,
    pub bank: ParamBank,
    /// quantizer for the main blocks (k = variant.block_k())
    q_block: ScalarQuantizer,
    /// quantizer for the rotor baseline's ragged tail (k = 2)
    q_tail: ScalarQuantizer,
    /// √d
    scale: f32,
    /// rotors precomputed from the quaternion bank (Rotor3D only)
    rotors: Vec<Rotor>,
    /// resolved kernel backend + SoA parameter copy (see `quant::kernels`)
    kern: KernelState,
}

impl Stage1 {
    pub fn new(cfg: Stage1Config) -> Stage1 {
        let bank = ParamBank::random(cfg.variant, cfg.d, cfg.seed);
        Stage1::with_bank(cfg, bank)
    }

    pub fn with_bank(cfg: Stage1Config, bank: ParamBank) -> Stage1 {
        assert_eq!(bank.variant, cfg.variant);
        assert_eq!(bank.d, cfg.d);
        let q_block = ScalarQuantizer::for_kind(cfg.quant, cfg.variant.block_k(), cfg.bits);
        let q_tail = ScalarQuantizer::for_kind(cfg.quant, 2, cfg.bits);
        let rotors = bank.q_l.iter().map(|&q| Rotor::from_quaternion(q)).collect();
        let kern = KernelState::build(cfg.backend, &bank, cfg.variant, cfg.rotor_impl);
        Stage1 {
            scale: (cfg.d as f32).sqrt(),
            q_block,
            q_tail,
            rotors,
            kern,
            bank,
            cfg,
        }
    }

    pub fn d(&self) -> usize {
        self.cfg.d
    }

    /// The kernel implementation this instance actually runs (what the
    /// `backend` request resolved to on this host).
    pub fn kernel_backend(&self) -> kernels::Resolved {
        self.kern.resolved
    }

    /// Bytes per compressed vector: packed codes + f32 norm.
    pub fn encoded_len(&self) -> usize {
        packing::packed_len(self.n_codes(), self.cfg.bits) + 4
    }

    /// Number of quantized coordinates per vector (includes padding for
    /// non-multiple dims, matching the HLO graphs).
    pub fn n_codes(&self) -> usize {
        match self.cfg.variant {
            Variant::IsoFull | Variant::IsoFast => self.cfg.d.div_ceil(4) * 4,
            Variant::Planar2D => self.cfg.d.div_ceil(2) * 2,
            Variant::Rotor3D | Variant::Dense => self.cfg.d,
            Variant::Grouped8D => self.cfg.d.div_ceil(8) * 8,
        }
    }

    // ------------------------------------------------------------------
    // fused quantize→dequantize (Table 2's measured path)
    // ------------------------------------------------------------------

    /// Fused stage-1 roundtrip of one vector (`x.len() == d`).
    pub fn roundtrip(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cfg.d);
        debug_assert_eq!(out.len(), self.cfg.d);
        let rho = l2_norm(x);
        let pre = self.scale / rho.max(EPS); // x → √d·x̄
        let post = rho / self.scale;
        match self.cfg.variant {
            Variant::IsoFull => self.rt_full(x, out, pre, post),
            Variant::IsoFast => self.rt_fast(x, out, pre, post),
            Variant::Planar2D => self.rt_planar(x, out, pre, post),
            Variant::Rotor3D => self.rt_rotor(x, out, pre, post),
            Variant::Dense => self.rt_dense(x, out, pre, post),
            Variant::Grouped8D => self.rt_grouped8(x, out, pre, post),
        }
    }

    /// Batch roundtrip over row-major `x` (n × d).
    pub fn roundtrip_batch(&self, x: &[f32], out: &mut [f32], n: usize) {
        debug_assert_eq!(x.len(), n * self.cfg.d);
        debug_assert_eq!(out.len(), n * self.cfg.d);
        let d = self.cfg.d;
        for i in 0..n {
            self.roundtrip(&x[i * d..(i + 1) * d], &mut out[i * d..(i + 1) * d]);
        }
    }

    /// fp16 execution-dtype model: inputs/outputs are binary16; arithmetic
    /// in f32 with intermediate rounding at the load/store boundaries
    /// (what a fused fp16 CUDA kernel with fp32 accumulators does).
    pub fn roundtrip_batch_f16(&self, x: &[u16], out: &mut [u16], n: usize) {
        let d = self.cfg.d;
        debug_assert_eq!(x.len(), n * d);
        let mut xin = vec![0.0f32; d];
        let mut xout = vec![0.0f32; d];
        for i in 0..n {
            for (j, &h) in x[i * d..(i + 1) * d].iter().enumerate() {
                xin[j] = f16::f16_bits_to_f32(h);
            }
            self.roundtrip(&xin, &mut xout);
            for (j, &v) in xout.iter().enumerate() {
                out[i * d + j] = f16::f32_to_f16_bits(v);
            }
        }
    }

    // ------------------------------------------------------------------
    // encode / decode (the compressed KV-cache representation)
    // ------------------------------------------------------------------

    /// Compress one vector into `(norm, packed codes)` appended to `out`.
    pub fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(x.len(), self.cfg.d);
        let rho = l2_norm(x);
        let pre = self.scale / rho.max(EPS);
        let mut codes = Vec::with_capacity(self.n_codes());
        self.rotate_quantize_codes(x, pre, &mut codes);
        out.extend_from_slice(&rho.to_le_bytes());
        packing::pack_append(&codes, self.cfg.bits, out);
    }

    /// Decompress one vector previously produced by [`Stage1::encode`].
    pub fn decode(&self, data: &[u8], out: &mut [f32]) {
        debug_assert_eq!(data.len(), self.encoded_len());
        debug_assert_eq!(out.len(), self.cfg.d);
        let rho = f32::from_le_bytes([data[0], data[1], data[2], data[3]]);
        let mut codes = Vec::with_capacity(self.n_codes());
        packing::unpack(&data[4..], self.cfg.bits, self.n_codes(), &mut codes);
        let post = rho / self.scale;
        self.dequantize_unrotate(&codes, post, out);
    }

    // ------------------------------------------------------------------
    // batched encode / decode (the page-granular serving hot path)
    // ------------------------------------------------------------------

    /// Compress `n_vecs` row-major `d`-vectors into `sink` as one
    /// contiguous run of `encoded_len()`-byte records.
    ///
    /// Record `i` is byte-identical to what [`Stage1::encode`] appends
    /// for `x[i*d..(i+1)*d]`; the parameter bank, quantizer tables, and
    /// scratch buffers are hoisted out of the per-vector loop and the
    /// sink's capacity persists across calls (zero steady-state
    /// allocation once warm).
    pub fn encode_batch(&self, x: &[f32], n_vecs: usize, sink: &mut PackedSink) {
        let d = self.cfg.d;
        assert_eq!(x.len(), n_vecs * d, "encode_batch: x must be n_vecs × d");
        let enc = self.encoded_len();
        let nc = self.n_codes();
        sink.encoded_len = enc;
        sink.n_vecs = n_vecs;
        sink.bytes.clear();
        sink.bytes.reserve(n_vecs * enc);
        let mut i = 0usize;
        // block-major SIMD tiles: `tile` vectors at a time, the block
        // sandwich vertical across vectors (see quant::kernels)
        let tile = kernels::tile_width(&self.kern, self.cfg.variant, d);
        if tile > 1 {
            // every row position is overwritten below (kernel prefix +
            // scalar tail), so a plain resize keeps the buffers warm
            sink.tile_codes.resize(tile * nc, 0);
            sink.rhos.resize(tile, 0.0);
            sink.pres.resize(tile, 0.0);
            while i + tile <= n_vecs {
                for v in 0..tile {
                    let rho = l2_norm(&x[(i + v) * d..(i + v + 1) * d]);
                    sink.rhos[v] = rho;
                    sink.pres[v] = self.scale / rho.max(EPS);
                }
                let covered = kernels::encode_tile_prefix(
                    &self.kern,
                    self.cfg.variant,
                    &self.q_block,
                    d,
                    &x[i * d..(i + tile) * d],
                    &sink.pres,
                    &mut sink.tile_codes,
                    nc,
                );
                for v in 0..tile {
                    // scalar reference finishes each row's ragged tail,
                    // then the row packs exactly like the per-vector path
                    let pre = sink.pres[v];
                    let rho = sink.rhos[v];
                    self.rotate_quantize_codes_from(
                        &x[(i + v) * d..(i + v + 1) * d],
                        pre,
                        &mut sink.tile_codes[v * nc..(v + 1) * nc],
                        covered,
                    );
                    sink.bytes.extend_from_slice(&rho.to_le_bytes());
                    packing::pack_append(
                        &sink.tile_codes[v * nc..(v + 1) * nc],
                        self.cfg.bits,
                        &mut sink.bytes,
                    );
                }
                i += tile;
            }
        }
        for i in i..n_vecs {
            let xi = &x[i * d..(i + 1) * d];
            let rho = l2_norm(xi);
            let pre = self.scale / rho.max(EPS);
            sink.codes.clear();
            self.rotate_quantize_codes(xi, pre, &mut sink.codes);
            sink.bytes.extend_from_slice(&rho.to_le_bytes());
            packing::pack_append(&sink.codes, self.cfg.bits, &mut sink.bytes);
        }
    }

    /// Decode `n_vecs` records stored contiguously (`stride ==
    /// encoded_len()`) into `out` (`n_vecs × d`).  See
    /// [`Stage1::decode_batch_strided`].
    pub fn decode_batch(
        &self,
        data: &[u8],
        n_vecs: usize,
        out: &mut [f32],
        scratch: &mut BatchScratch,
    ) {
        self.decode_batch_strided(data, self.encoded_len(), n_vecs, out, scratch);
    }

    /// Decode `n_vecs` encoded records spaced `stride` bytes apart in
    /// `data` (record `i` at `data[i*stride..i*stride+encoded_len()]`)
    /// straight into the contiguous destination `out[i*d..(i+1)*d]`.
    ///
    /// This is the KV-page gather kernel: a page stores one token slot
    /// every `PageConfig::slot_bytes()`, so a (layer, head) column of a
    /// page is exactly a strided record run, and the destination is the
    /// lane-major `[t][dh]` gather layout.  Bit-exact with per-vector
    /// [`Stage1::decode`]; no per-vector allocation (scratch is reused).
    pub fn decode_batch_strided(
        &self,
        data: &[u8],
        stride: usize,
        n_vecs: usize,
        out: &mut [f32],
        scratch: &mut BatchScratch,
    ) {
        let d = self.cfg.d;
        let enc = self.encoded_len();
        let nc = self.n_codes();
        let bits = self.cfg.bits;
        assert!(stride >= enc, "decode_batch_strided: stride {stride} < encoded_len {enc}");
        assert_eq!(out.len(), n_vecs * d, "decode_batch_strided: out must be n_vecs × d");
        if n_vecs == 0 {
            return;
        }
        assert!(
            data.len() >= (n_vecs - 1) * stride + enc,
            "decode_batch_strided: data too short for {n_vecs} records"
        );
        let mut i = 0usize;
        // block-major SIMD tiles: `tile` records at a time, the inverse
        // sandwich vertical across vectors (the KV-gather hot shape)
        let tile = kernels::tile_width(&self.kern, self.cfg.variant, d);
        if tile > 1 {
            // unpack_into rewrites every row position, so a plain resize
            // keeps the buffers warm across calls
            scratch.tile_codes.resize(tile * nc, 0);
            scratch.posts.resize(tile, 0.0);
            while i + tile <= n_vecs {
                for v in 0..tile {
                    let rec = &data[(i + v) * stride..(i + v) * stride + enc];
                    let rho = f32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
                    scratch.posts[v] = rho / self.scale;
                    // dispatched nibble/crumb expansion (scalar for
                    // 3-bit); bit-exact with packing::unpack_into
                    kernels::unpack_codes(
                        &self.kern,
                        &rec[4..],
                        bits,
                        nc,
                        &mut scratch.tile_codes[v * nc..(v + 1) * nc],
                    );
                }
                let covered = kernels::decode_tile_prefix(
                    &self.kern,
                    self.cfg.variant,
                    &self.q_block,
                    d,
                    &scratch.tile_codes,
                    nc,
                    &scratch.posts,
                    &mut out[i * d..(i + tile) * d],
                );
                for v in 0..tile {
                    // scalar reference finishes each row's ragged tail
                    self.dequantize_unrotate_from(
                        &scratch.tile_codes[v * nc..(v + 1) * nc],
                        scratch.posts[v],
                        &mut out[(i + v) * d..(i + v + 1) * d],
                        covered,
                    );
                }
                i += tile;
            }
        }
        for i in i..n_vecs {
            let rec = &data[i * stride..i * stride + enc];
            let rho = f32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
            let post = rho / self.scale;
            packing::unpack(&rec[4..], bits, nc, &mut scratch.codes);
            self.dequantize_unrotate(&scratch.codes, post, &mut out[i * d..(i + 1) * d]);
        }
    }

    /// [`Stage1::decode_batch_strided`] with binary16 output: every
    /// element of `out` equals `f16::f32_to_f16_bits` of the f32 the
    /// strided decode would produce (round-to-nearest-even at the store
    /// boundary — the paper's FP16 row target at half the gather write
    /// bandwidth).  Backends with an F16C tile convert in-register; all
    /// other paths decode f32 into scratch and convert scalar-wise,
    /// which produces the same bits by the conversion contract.
    pub fn decode_batch_strided_f16(
        &self,
        data: &[u8],
        stride: usize,
        n_vecs: usize,
        out: &mut [u16],
        scratch: &mut BatchScratch,
    ) {
        let d = self.cfg.d;
        let enc = self.encoded_len();
        let nc = self.n_codes();
        let bits = self.cfg.bits;
        assert!(stride >= enc, "decode_batch_strided_f16: stride {stride} < encoded_len {enc}");
        assert_eq!(out.len(), n_vecs * d, "decode_batch_strided_f16: out must be n_vecs × d");
        if n_vecs == 0 {
            return;
        }
        assert!(
            data.len() >= (n_vecs - 1) * stride + enc,
            "decode_batch_strided_f16: data too short for {n_vecs} records"
        );
        let mut i = 0usize;
        let tile = kernels::tile_width(&self.kern, self.cfg.variant, d);
        if tile > 1 {
            scratch.tile_codes.resize(tile * nc, 0);
            scratch.posts.resize(tile, 0.0);
            scratch.frow.resize(d, 0.0);
            while i + tile <= n_vecs {
                for v in 0..tile {
                    let rec = &data[(i + v) * stride..(i + v) * stride + enc];
                    let rho = f32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
                    scratch.posts[v] = rho / self.scale;
                    kernels::unpack_codes(
                        &self.kern,
                        &rec[4..],
                        bits,
                        nc,
                        &mut scratch.tile_codes[v * nc..(v + 1) * nc],
                    );
                }
                let mut covered = kernels::decode_tile_prefix_f16(
                    &self.kern,
                    self.cfg.variant,
                    &self.q_block,
                    d,
                    &scratch.tile_codes,
                    nc,
                    &scratch.posts,
                    &mut out[i * d..(i + tile) * d],
                );
                if covered == 0 {
                    // no native f16 tile on this backend: decode the f32
                    // tile into staging and convert (same bits — the
                    // conversion contract in util::f16)
                    scratch.fstage.resize(tile * d, 0.0);
                    covered = kernels::decode_tile_prefix(
                        &self.kern,
                        self.cfg.variant,
                        &self.q_block,
                        d,
                        &scratch.tile_codes,
                        nc,
                        &scratch.posts,
                        &mut scratch.fstage,
                    );
                    for v in 0..tile {
                        for j in 0..covered {
                            out[(i + v) * d + j] =
                                f16::f32_to_f16_bits(scratch.fstage[v * d + j]);
                        }
                    }
                }
                if covered < d {
                    // scalar reference finishes each row's ragged tail
                    // in f32, converted at the store boundary
                    for v in 0..tile {
                        self.dequantize_unrotate_from(
                            &scratch.tile_codes[v * nc..(v + 1) * nc],
                            scratch.posts[v],
                            &mut scratch.frow,
                            covered,
                        );
                        for j in covered..d {
                            out[(i + v) * d + j] = f16::f32_to_f16_bits(scratch.frow[j]);
                        }
                    }
                }
                i += tile;
            }
        }
        for i in i..n_vecs {
            let rec = &data[i * stride..i * stride + enc];
            let rho = f32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
            let post = rho / self.scale;
            packing::unpack(&rec[4..], bits, nc, &mut scratch.codes);
            scratch.frow.resize(d, 0.0);
            self.dequantize_unrotate(&scratch.codes, post, &mut scratch.frow);
            for j in 0..d {
                out[i * d + j] = f16::f32_to_f16_bits(scratch.frow[j]);
            }
        }
    }

    // ------------------------------------------------------------------
    // per-variant fused bodies
    // ------------------------------------------------------------------

    fn rt_full(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        let full = d / 4;
        for b in 0..full {
            let i = b * 4;
            let v = [x[i] * pre, x[i + 1] * pre, x[i + 2] * pre, x[i + 3] * pre];
            let y = quat::sandwich(self.bank.q_l[b], v, self.bank.q_r[b]);
            let yq = [
                self.q_block.qdq1(y[0]),
                self.q_block.qdq1(y[1]),
                self.q_block.qdq1(y[2]),
                self.q_block.qdq1(y[3]),
            ];
            let r = quat::sandwich_inv(self.bank.q_l[b], yq, self.bank.q_r[b]);
            out[i] = r[0] * post;
            out[i + 1] = r[1] * post;
            out[i + 2] = r[2] * post;
            out[i + 3] = r[3] * post;
        }
        if d % 4 != 0 {
            let b = full;
            let i = b * 4;
            let mut v = [0.0f32; 4];
            for (j, slot) in v.iter_mut().enumerate().take(d - i) {
                *slot = x[i + j] * pre;
            }
            let y = quat::sandwich(self.bank.q_l[b], v, self.bank.q_r[b]);
            let yq = [
                self.q_block.qdq1(y[0]),
                self.q_block.qdq1(y[1]),
                self.q_block.qdq1(y[2]),
                self.q_block.qdq1(y[3]),
            ];
            let r = quat::sandwich_inv(self.bank.q_l[b], yq, self.bank.q_r[b]);
            for j in 0..(d - i) {
                out[i + j] = r[j] * post;
            }
        }
    }

    fn rt_fast(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        let full = d / 4;
        for b in 0..full {
            let i = b * 4;
            let v = [x[i] * pre, x[i + 1] * pre, x[i + 2] * pre, x[i + 3] * pre];
            let y = quat::hamilton(self.bank.q_l[b], v);
            let yq = [
                self.q_block.qdq1(y[0]),
                self.q_block.qdq1(y[1]),
                self.q_block.qdq1(y[2]),
                self.q_block.qdq1(y[3]),
            ];
            let r = quat::hamilton(quat::conjugate(self.bank.q_l[b]), yq);
            out[i] = r[0] * post;
            out[i + 1] = r[1] * post;
            out[i + 2] = r[2] * post;
            out[i + 3] = r[3] * post;
        }
        if d % 4 != 0 {
            let b = full;
            let i = b * 4;
            let mut v = [0.0f32; 4];
            for (j, slot) in v.iter_mut().enumerate().take(d - i) {
                *slot = x[i + j] * pre;
            }
            let y = quat::hamilton(self.bank.q_l[b], v);
            let yq: [f32; 4] = std::array::from_fn(|j| self.q_block.qdq1(y[j]));
            let r = quat::hamilton(quat::conjugate(self.bank.q_l[b]), yq);
            for j in 0..(d - i) {
                out[i + j] = r[j] * post;
            }
        }
    }

    fn rt_planar(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        let full = d / 2;
        for b in 0..full {
            let i = b * 2;
            let (c, s) = self.bank.cos_sin[b];
            let u0 = x[i] * pre;
            let u1 = x[i + 1] * pre;
            let y0 = self.q_block.qdq1(c * u0 - s * u1);
            let y1 = self.q_block.qdq1(s * u0 + c * u1);
            out[i] = (c * y0 + s * y1) * post;
            out[i + 1] = (-s * y0 + c * y1) * post;
        }
        if d % 2 != 0 {
            let b = full;
            let (c, s) = self.bank.cos_sin[b];
            let u0 = x[d - 1] * pre;
            let y0 = self.q_block.qdq1(c * u0);
            let y1 = self.q_block.qdq1(s * u0);
            out[d - 1] = (c * y0 + s * y1) * post;
        }
    }

    #[inline(always)]
    fn rotor_fwd(&self, b: usize, v: [f32; 3]) -> [f32; 3] {
        match self.cfg.rotor_impl {
            RotorImpl::Multivector => {
                crate::math::rotor3::sandwich_multivector(self.rotors[b], v)
            }
            RotorImpl::OddIntermediate => self.rotors[b].apply(v),
        }
    }

    #[inline(always)]
    fn rotor_inv(&self, b: usize, v: [f32; 3]) -> [f32; 3] {
        match self.cfg.rotor_impl {
            RotorImpl::Multivector => {
                crate::math::rotor3::sandwich_multivector(self.rotors[b].reverse(), v)
            }
            RotorImpl::OddIntermediate => self.rotors[b].apply_inv(v),
        }
    }

    fn rt_rotor(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        let nfull = d / 3;
        for b in 0..nfull {
            let i = b * 3;
            let v = [x[i] * pre, x[i + 1] * pre, x[i + 2] * pre];
            let y = self.rotor_fwd(b, v);
            let yq = [
                self.q_block.qdq1(y[0]),
                self.q_block.qdq1(y[1]),
                self.q_block.qdq1(y[2]),
            ];
            let r = self.rotor_inv(b, yq);
            out[i] = r[0] * post;
            out[i + 1] = r[1] * post;
            out[i + 2] = r[2] * post;
        }
        match d % 3 {
            2 => {
                let i = 3 * nfull;
                let (c, s) = self.bank.cos_sin[0];
                let u0 = x[i] * pre;
                let u1 = x[i + 1] * pre;
                let y0 = self.q_tail.qdq1(c * u0 - s * u1);
                let y1 = self.q_tail.qdq1(s * u0 + c * u1);
                out[i] = (c * y0 + s * y1) * post;
                out[i + 1] = (-s * y0 + c * y1) * post;
            }
            1 => {
                let i = 3 * nfull;
                out[i] = self.q_tail.qdq1(x[i] * pre) * post;
            }
            _ => {}
        }
    }

    fn rt_dense(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        // y = M · (pre·x); quantize; rec = Mᵀ · yq; out = post · rec
        let mut y = vec![0.0f32; d];
        for i in 0..d {
            let row = &self.bank.dense[i * d..(i + 1) * d];
            let mut s = 0.0f32;
            for j in 0..d {
                s += row[j] * x[j];
            }
            y[i] = self.q_block.qdq1(s * pre);
        }
        out.fill(0.0);
        for i in 0..d {
            let row = &self.bank.dense[i * d..(i + 1) * d];
            let yv = y[i];
            for j in 0..d {
                out[j] += yv * row[j];
            }
        }
        for o in out.iter_mut() {
            *o *= post;
        }
    }

    fn rt_grouped8(&self, x: &[f32], out: &mut [f32], pre: f32, post: f32) {
        let d = self.cfg.d;
        let g8 = d.div_ceil(8);
        for b in 0..g8 {
            let base = b * 8;
            let mut v = [0.0f32; 8];
            for (j, slot) in v.iter_mut().enumerate() {
                if base + j < d {
                    *slot = x[base + j] * pre;
                }
            }
            // stage A: rotate both 4-halves with pair 2b
            let (qa_l, qa_r) = (self.bank.q_l[2 * b], self.bank.q_r[2 * b]);
            let lo = quat::sandwich(qa_l, [v[0], v[1], v[2], v[3]], qa_r);
            let hi = quat::sandwich(qa_l, [v[4], v[5], v[6], v[7]], qa_r);
            let merged = [lo[0], lo[1], lo[2], lo[3], hi[0], hi[1], hi[2], hi[3]];
            // interleave, then stage B with pair 2b+1
            let mut mixed = [0.0f32; 8];
            for (dst, &src) in P8.iter().enumerate() {
                mixed[dst] = merged[src];
            }
            let (qb_l, qb_r) = (self.bank.q_l[2 * b + 1], self.bank.q_r[2 * b + 1]);
            let lo2 = quat::sandwich(qb_l, [mixed[0], mixed[1], mixed[2], mixed[3]], qb_r);
            let hi2 = quat::sandwich(qb_l, [mixed[4], mixed[5], mixed[6], mixed[7]], qb_r);
            let yq: [f32; 8] = std::array::from_fn(|j| {
                self.q_block.qdq1(if j < 4 { lo2[j] } else { hi2[j - 4] })
            });
            // inverse: stage B⁻¹, deinterleave, stage A⁻¹
            let lo3 = quat::sandwich_inv(qb_l, [yq[0], yq[1], yq[2], yq[3]], qb_r);
            let hi3 = quat::sandwich_inv(qb_l, [yq[4], yq[5], yq[6], yq[7]], qb_r);
            let back = [lo3[0], lo3[1], lo3[2], lo3[3], hi3[0], hi3[1], hi3[2], hi3[3]];
            let mut unmixed = [0.0f32; 8];
            for (dst, &src) in P8.iter().enumerate() {
                unmixed[src] = back[dst];
            }
            let lo4 = quat::sandwich_inv(qa_l, [unmixed[0], unmixed[1], unmixed[2], unmixed[3]], qa_r);
            let hi4 = quat::sandwich_inv(qa_l, [unmixed[4], unmixed[5], unmixed[6], unmixed[7]], qa_r);
            for j in 0..8 {
                if base + j < d {
                    out[base + j] = (if j < 4 { lo4[j] } else { hi4[j - 4] }) * post;
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // encode/decode internals (shared rotate-then-code body)
    // ------------------------------------------------------------------

    fn rotate_quantize_codes(&self, x: &[f32], pre: f32, codes: &mut Vec<u8>) {
        codes.clear();
        codes.resize(self.n_codes(), 0);
        let done = kernels::encode_prefix(
            &self.kern,
            self.cfg.variant,
            &self.q_block,
            self.cfg.d,
            x,
            pre,
            codes,
        );
        self.rotate_quantize_codes_from(x, pre, codes, done);
    }

    /// The scalar reference encode body, from code position `start`
    /// (block-aligned) onward — `start = 0` is the full reference path,
    /// non-zero finishes what a SIMD prefix left (ragged tails, sub-tile
    /// remainders).  Retained verbatim modulo indexed writes.
    fn rotate_quantize_codes_from(&self, x: &[f32], pre: f32, codes: &mut [u8], start: usize) {
        let d = self.cfg.d;
        match self.cfg.variant {
            Variant::IsoFull => {
                let g = d.div_ceil(4);
                for b in start / 4..g {
                    let i = b * 4;
                    let mut v = [0.0f32; 4];
                    for (j, slot) in v.iter_mut().enumerate() {
                        if i + j < d {
                            *slot = x[i + j] * pre;
                        }
                    }
                    let y = quat::sandwich(self.bank.q_l[b], v, self.bank.q_r[b]);
                    for (j, yy) in y.into_iter().enumerate() {
                        codes[i + j] = self.q_block.encode1(yy);
                    }
                }
            }
            Variant::IsoFast => {
                let g = d.div_ceil(4);
                for b in start / 4..g {
                    let i = b * 4;
                    let mut v = [0.0f32; 4];
                    for (j, slot) in v.iter_mut().enumerate() {
                        if i + j < d {
                            *slot = x[i + j] * pre;
                        }
                    }
                    let y = quat::hamilton(self.bank.q_l[b], v);
                    for (j, yy) in y.into_iter().enumerate() {
                        codes[i + j] = self.q_block.encode1(yy);
                    }
                }
            }
            Variant::Planar2D => {
                let g = d.div_ceil(2);
                for b in start / 2..g {
                    let i = b * 2;
                    let (c, s) = self.bank.cos_sin[b];
                    let u0 = x[i] * pre;
                    let u1 = if i + 1 < d { x[i + 1] * pre } else { 0.0 };
                    codes[i] = self.q_block.encode1(c * u0 - s * u1);
                    codes[i + 1] = self.q_block.encode1(s * u0 + c * u1);
                }
            }
            Variant::Rotor3D => {
                debug_assert_eq!(start % 3, 0, "Rotor3D SIMD prefix covers whole blocks");
                let nfull = d / 3;
                for b in start / 3..nfull {
                    let i = b * 3;
                    let y = self.rotor_fwd(b, [x[i] * pre, x[i + 1] * pre, x[i + 2] * pre]);
                    for (j, yy) in y.into_iter().enumerate() {
                        codes[i + j] = self.q_block.encode1(yy);
                    }
                }
                match d % 3 {
                    2 => {
                        let i = 3 * nfull;
                        let (c, s) = self.bank.cos_sin[0];
                        let u0 = x[i] * pre;
                        let u1 = x[i + 1] * pre;
                        codes[i] = self.q_tail.encode1(c * u0 - s * u1);
                        codes[i + 1] = self.q_tail.encode1(s * u0 + c * u1);
                    }
                    1 => codes[d - 1] = self.q_tail.encode1(x[3 * nfull] * pre),
                    _ => {}
                }
            }
            Variant::Dense => {
                debug_assert_eq!(start, 0, "Dense has no SIMD prefix");
                for i in 0..d {
                    let row = &self.bank.dense[i * d..(i + 1) * d];
                    let mut s = 0.0f32;
                    for j in 0..d {
                        s += row[j] * x[j];
                    }
                    codes[i] = self.q_block.encode1(s * pre);
                }
            }
            Variant::Grouped8D => {
                debug_assert_eq!(start, 0, "Grouped8D has no SIMD prefix");
                // reuse the fused body through a temporary: encode is not
                // on the grouped variant's hot path (ablation only)
                let g8 = d.div_ceil(8);
                for b in 0..g8 {
                    let base = b * 8;
                    let mut v = [0.0f32; 8];
                    for (j, slot) in v.iter_mut().enumerate() {
                        if base + j < d {
                            *slot = x[base + j] * pre;
                        }
                    }
                    let (qa_l, qa_r) = (self.bank.q_l[2 * b], self.bank.q_r[2 * b]);
                    let lo = quat::sandwich(qa_l, [v[0], v[1], v[2], v[3]], qa_r);
                    let hi = quat::sandwich(qa_l, [v[4], v[5], v[6], v[7]], qa_r);
                    let merged = [lo[0], lo[1], lo[2], lo[3], hi[0], hi[1], hi[2], hi[3]];
                    let mut mixed = [0.0f32; 8];
                    for (dst, &src) in P8.iter().enumerate() {
                        mixed[dst] = merged[src];
                    }
                    let (qb_l, qb_r) = (self.bank.q_l[2 * b + 1], self.bank.q_r[2 * b + 1]);
                    let lo2 = quat::sandwich(qb_l, [mixed[0], mixed[1], mixed[2], mixed[3]], qb_r);
                    let hi2 = quat::sandwich(qb_l, [mixed[4], mixed[5], mixed[6], mixed[7]], qb_r);
                    for j in 0..8 {
                        let y = if j < 4 { lo2[j] } else { hi2[j - 4] };
                        codes[base + j] = self.q_block.encode1(y);
                    }
                }
            }
        }
    }

    fn dequantize_unrotate(&self, codes: &[u8], post: f32, out: &mut [f32]) {
        let done = kernels::decode_prefix(
            &self.kern,
            self.cfg.variant,
            &self.q_block,
            self.cfg.d,
            codes,
            post,
            out,
        );
        self.dequantize_unrotate_from(codes, post, out, done);
    }

    /// The scalar reference decode body, from code position `start`
    /// (block-aligned) onward — the exact inverse counterpart of
    /// [`Stage1::rotate_quantize_codes_from`].
    fn dequantize_unrotate_from(&self, codes: &[u8], post: f32, out: &mut [f32], start: usize) {
        let d = self.cfg.d;
        match self.cfg.variant {
            Variant::IsoFull => {
                for b in start / 4..d.div_ceil(4) {
                    let i = b * 4;
                    let yq: [f32; 4] =
                        std::array::from_fn(|j| self.q_block.decode1(codes[i + j]));
                    let r = quat::sandwich_inv(self.bank.q_l[b], yq, self.bank.q_r[b]);
                    for j in 0..4 {
                        if i + j < d {
                            out[i + j] = r[j] * post;
                        }
                    }
                }
            }
            Variant::IsoFast => {
                for b in start / 4..d.div_ceil(4) {
                    let i = b * 4;
                    let yq: [f32; 4] =
                        std::array::from_fn(|j| self.q_block.decode1(codes[i + j]));
                    let r = quat::hamilton(quat::conjugate(self.bank.q_l[b]), yq);
                    for j in 0..4 {
                        if i + j < d {
                            out[i + j] = r[j] * post;
                        }
                    }
                }
            }
            Variant::Planar2D => {
                for b in start / 2..d.div_ceil(2) {
                    let i = b * 2;
                    let (c, s) = self.bank.cos_sin[b];
                    let y0 = self.q_block.decode1(codes[i]);
                    let y1 = self.q_block.decode1(codes[i + 1]);
                    out[i] = (c * y0 + s * y1) * post;
                    if i + 1 < d {
                        out[i + 1] = (-s * y0 + c * y1) * post;
                    }
                }
            }
            Variant::Rotor3D => {
                debug_assert_eq!(start % 3, 0, "Rotor3D SIMD prefix covers whole blocks");
                let nfull = d / 3;
                for b in start / 3..nfull {
                    let i = b * 3;
                    let yq = [
                        self.q_block.decode1(codes[i]),
                        self.q_block.decode1(codes[i + 1]),
                        self.q_block.decode1(codes[i + 2]),
                    ];
                    let r = self.rotor_inv(b, yq);
                    out[i] = r[0] * post;
                    out[i + 1] = r[1] * post;
                    out[i + 2] = r[2] * post;
                }
                match d % 3 {
                    2 => {
                        let i = 3 * nfull;
                        let (c, s) = self.bank.cos_sin[0];
                        let y0 = self.q_tail.decode1(codes[i]);
                        let y1 = self.q_tail.decode1(codes[i + 1]);
                        out[i] = (c * y0 + s * y1) * post;
                        out[i + 1] = (-s * y0 + c * y1) * post;
                    }
                    1 => {
                        let i = 3 * nfull;
                        out[i] = self.q_tail.decode1(codes[i]) * post;
                    }
                    _ => {}
                }
            }
            Variant::Dense => {
                debug_assert_eq!(start, 0, "Dense has no SIMD prefix");
                out.fill(0.0);
                for i in 0..d {
                    let row = &self.bank.dense[i * d..(i + 1) * d];
                    let yv = self.q_block.decode1(codes[i]);
                    for j in 0..d {
                        out[j] += yv * row[j];
                    }
                }
                for o in out.iter_mut() {
                    *o *= post;
                }
            }
            Variant::Grouped8D => {
                debug_assert_eq!(start, 0, "Grouped8D has no SIMD prefix");
                for b in 0..d.div_ceil(8) {
                    let base = b * 8;
                    let yq: [f32; 8] =
                        std::array::from_fn(|j| self.q_block.decode1(codes[base + j]));
                    let (qa_l, qa_r) = (self.bank.q_l[2 * b], self.bank.q_r[2 * b]);
                    let (qb_l, qb_r) = (self.bank.q_l[2 * b + 1], self.bank.q_r[2 * b + 1]);
                    let lo3 = quat::sandwich_inv(qb_l, [yq[0], yq[1], yq[2], yq[3]], qb_r);
                    let hi3 = quat::sandwich_inv(qb_l, [yq[4], yq[5], yq[6], yq[7]], qb_r);
                    let back = [lo3[0], lo3[1], lo3[2], lo3[3], hi3[0], hi3[1], hi3[2], hi3[3]];
                    let mut unmixed = [0.0f32; 8];
                    for (dst, &src) in P8.iter().enumerate() {
                        unmixed[src] = back[dst];
                    }
                    let lo4 =
                        quat::sandwich_inv(qa_l, [unmixed[0], unmixed[1], unmixed[2], unmixed[3]], qa_r);
                    let hi4 =
                        quat::sandwich_inv(qa_l, [unmixed[4], unmixed[5], unmixed[6], unmixed[7]], qa_r);
                    for j in 0..8 {
                        if base + j < d {
                            out[base + j] = (if j < 4 { lo4[j] } else { hi4[j - 4] }) * post;
                        }
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Unfused "module-level" path (paper §9.4): separate normalize / rotate /
// quantize / dequantize / unrotate passes with materialized per-block
// rotation matrices and intermediate buffers — models a naive PyTorch
// module composition.
// ----------------------------------------------------------------------

/// Unfused reference: multiple passes, heap intermediates, dense 4×4
/// (or 3×3-in-multivector) block matrices.
pub struct Stage1Unfused {
    fused: Stage1,
    /// materialized per-block matrices (IsoFull/IsoFast/Grouped8D)
    block_mats: Vec<[f32; 16]>,
}

impl Stage1Unfused {
    pub fn new(cfg: Stage1Config) -> Stage1Unfused {
        let fused = Stage1::new(cfg);
        Stage1Unfused::from_fused(fused)
    }

    pub fn from_fused(fused: Stage1) -> Stage1Unfused {
        use crate::math::so4;
        let block_mats = match fused.cfg.variant {
            Variant::IsoFull => fused
                .bank
                .q_l
                .iter()
                .zip(&fused.bank.q_r)
                .map(|(&l, &r)| so4::isoclinic_matrix(l, r))
                .collect(),
            Variant::IsoFast => fused
                .bank
                .q_l
                .iter()
                .map(|&l| so4::left_isoclinic_matrix(l))
                .collect(),
            _ => Vec::new(),
        };
        Stage1Unfused { fused, block_mats }
    }

    /// Multi-pass roundtrip with per-stage buffers.
    pub fn roundtrip(&self, x: &[f32]) -> Vec<f32> {
        let d = self.fused.cfg.d;
        // pass 1: norm
        let rho = l2_norm(x);
        // pass 2: normalize (new buffer)
        let xbar: Vec<f32> = x.iter().map(|&v| v / rho.max(EPS)).collect();
        // pass 3: rotate (new buffer)
        let y = self.rotate_passes(&xbar);
        // pass 4: scale + quantize to indices (new buffer).  The rotor
        // baseline's ragged tail uses the k=2 quantizer, matching the
        // fused path.
        let s = self.fused.scale;
        let tail_start = match self.fused.cfg.variant {
            Variant::Rotor3D => 3 * (d / 3),
            _ => usize::MAX,
        };
        let qz = |i: usize| {
            if i >= tail_start {
                &self.fused.q_tail
            } else {
                &self.fused.q_block
            }
        };
        let codes: Vec<u8> = y
            .iter()
            .enumerate()
            .map(|(i, &v)| qz(i).encode1(v * s))
            .collect();
        // pass 5: dequantize (new buffer)
        let yq: Vec<f32> = codes
            .iter()
            .enumerate()
            .map(|(i, &c)| qz(i).decode1(c) / s)
            .collect();
        // pass 6: inverse rotate (new buffer)
        let rec = self.unrotate_passes(&yq);
        // pass 7: restore norm
        rec.iter().take(d).map(|&v| v * rho).collect()
    }

    fn rotate_passes(&self, xbar: &[f32]) -> Vec<f32> {
        use crate::math::rotor3::{sandwich_multivector, Rotor};
        use crate::math::so4;
        let d = self.fused.cfg.d;
        match self.fused.cfg.variant {
            Variant::IsoFull | Variant::IsoFast => {
                let g = d.div_ceil(4);
                let mut y = vec![0.0f32; g * 4];
                for b in 0..g {
                    let mut v = [0.0f32; 4];
                    for j in 0..4 {
                        if b * 4 + j < d {
                            v[j] = xbar[b * 4 + j];
                        }
                    }
                    let r = so4::matvec4(&self.block_mats[b], v);
                    y[b * 4..b * 4 + 4].copy_from_slice(&r);
                }
                y
            }
            Variant::Rotor3D => {
                let nfull = d / 3;
                let mut y = vec![0.0f32; d];
                for b in 0..nfull {
                    let i = b * 3;
                    let rot = Rotor::from_quaternion(self.fused.bank.q_l[b]);
                    // the 8-component multivector expansion (see rotor3.rs)
                    let r = sandwich_multivector(rot, [xbar[i], xbar[i + 1], xbar[i + 2]]);
                    y[i..i + 3].copy_from_slice(&r);
                }
                // tail: planar
                match d % 3 {
                    2 => {
                        let i = 3 * nfull;
                        let (c, s) = self.fused.bank.cos_sin[0];
                        y[i] = c * xbar[i] - s * xbar[i + 1];
                        y[i + 1] = s * xbar[i] + c * xbar[i + 1];
                    }
                    1 => y[d - 1] = xbar[d - 1],
                    _ => {}
                }
                y
            }
            _ => {
                // fall back to the fused rotation for variants whose
                // unfused path is not part of §9.4
                let mut codes = Vec::new();
                self.fused.rotate_quantize_codes(xbar, 1.0, &mut codes);
                codes
                    .iter()
                    .map(|&c| self.fused.q_block.decode1(c))
                    .collect()
            }
        }
    }

    fn unrotate_passes(&self, yq: &[f32]) -> Vec<f32> {
        use crate::math::rotor3::{sandwich_multivector, Rotor};
        let d = self.fused.cfg.d;
        match self.fused.cfg.variant {
            Variant::IsoFull | Variant::IsoFast => {
                let g = d.div_ceil(4);
                let mut out = vec![0.0f32; g * 4];
                for b in 0..g {
                    let m = &self.block_mats[b];
                    let v = [yq[b * 4], yq[b * 4 + 1], yq[b * 4 + 2], yq[b * 4 + 3]];
                    // Mᵀ v (inverse of orthogonal)
                    for j in 0..4 {
                        out[b * 4 + j] =
                            m[j] * v[0] + m[4 + j] * v[1] + m[8 + j] * v[2] + m[12 + j] * v[3];
                    }
                }
                out
            }
            Variant::Rotor3D => {
                let nfull = d / 3;
                let mut out = vec![0.0f32; d];
                for b in 0..nfull {
                    let i = b * 3;
                    let rot = Rotor::from_quaternion(self.fused.bank.q_l[b]).reverse();
                    let r = sandwich_multivector(rot, [yq[i], yq[i + 1], yq[i + 2]]);
                    out[i..i + 3].copy_from_slice(&r);
                }
                match d % 3 {
                    2 => {
                        let i = 3 * nfull;
                        let (c, s) = self.fused.bank.cos_sin[0];
                        out[i] = c * yq[i] + s * yq[i + 1];
                        out[i + 1] = -s * yq[i] + c * yq[i + 1];
                    }
                    1 => out[d - 1] = yq[d - 1],
                    _ => {}
                }
                out
            }
            _ => yq.to_vec(),
        }
    }
}

#[inline(always)]
pub fn l2_norm(x: &[f32]) -> f32 {
    // f64 accumulation: x ~ 1e30 would overflow an f32 sum of squares
    // and poison the whole pipeline with inf/NaN
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let e = (x - y) as f64;
            e * e
        })
        .sum::<f64>()
        / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gen_batch(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        rng.gaussian_vec_f32(n * d)
    }

    const ALL: [Variant; 6] = [
        Variant::IsoFull,
        Variant::IsoFast,
        Variant::Planar2D,
        Variant::Rotor3D,
        Variant::Dense,
        Variant::Grouped8D,
    ];

    #[test]
    fn roundtrip_reduces_like_quantizer_should() {
        // reconstruction error must decrease with bit width, per variant
        let mut rng = Rng::new(1);
        let d = 128;
        let x = gen_batch(&mut rng, 256, d);
        for v in ALL {
            let mut prev = f64::INFINITY;
            for bits in [2u8, 3, 4] {
                let s = Stage1::new(Stage1Config::new(v, d, bits));
                let mut out = vec![0.0f32; x.len()];
                s.roundtrip_batch(&x, &mut out, 256);
                let e = mse(&x, &out);
                assert!(e < prev, "{v:?} bits={bits}: {e} !< {prev}");
                assert!(e.is_finite());
                prev = e;
            }
        }
    }

    #[test]
    fn mse_sane_at_4_bits() {
        // at 4 bits the relative error should be well under 10%
        let mut rng = Rng::new(2);
        let d = 128;
        let n = 512;
        let x = gen_batch(&mut rng, n, d);
        let power = x.iter().map(|&v| (v * v) as f64).sum::<f64>() / x.len() as f64;
        for v in ALL {
            let s = Stage1::new(Stage1Config::new(v, d, 4));
            let mut out = vec![0.0f32; x.len()];
            s.roundtrip_batch(&x, &mut out, n);
            let rel = mse(&x, &out) / power;
            assert!(rel < 0.10, "{v:?}: rel mse {rel}");
        }
    }

    #[test]
    fn encode_decode_matches_roundtrip() {
        // the packed path and the fused qdq path must agree exactly
        let mut rng = Rng::new(3);
        for v in ALL {
            for d in [64usize, 128] {
                for bits in [2u8, 3, 4] {
                    let s = Stage1::new(Stage1Config::new(v, d, bits));
                    let x = rng.gaussian_vec_f32(d);
                    let mut fused = vec![0.0f32; d];
                    s.roundtrip(&x, &mut fused);
                    let mut enc = Vec::new();
                    s.encode(&x, &mut enc);
                    assert_eq!(enc.len(), s.encoded_len());
                    let mut dec = vec![0.0f32; d];
                    s.decode(&enc, &mut dec);
                    for i in 0..d {
                        assert!(
                            (fused[i] - dec[i]).abs() < 1e-5,
                            "{v:?} d={d} b={bits} i={i}: {} vs {}",
                            fused[i],
                            dec[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn odd_dims_supported() {
        let mut rng = Rng::new(4);
        for v in ALL {
            for d in [63usize, 65, 66, 127] {
                let s = Stage1::new(Stage1Config::new(v, d, 4));
                let x = rng.gaussian_vec_f32(d);
                let mut out = vec![0.0f32; d];
                s.roundtrip(&x, &mut out);
                assert!(out.iter().all(|o| o.is_finite()), "{v:?} d={d}");
                let rel = mse(&x, &out)
                    / (x.iter().map(|&v| (v * v) as f64).sum::<f64>() / d as f64);
                assert!(rel < 0.2, "{v:?} d={d}: rel {rel}");
            }
        }
    }

    #[test]
    fn scale_equivariance() {
        // xhat(c·x) == c·xhat(x) thanks to the norm split (paper eq. 3)
        let mut rng = Rng::new(5);
        let d = 64;
        let x = rng.gaussian_vec_f32(d);
        let x3: Vec<f32> = x.iter().map(|&v| 3.0 * v).collect();
        for v in ALL {
            let s = Stage1::new(Stage1Config::new(v, d, 3));
            let mut a = vec![0.0f32; d];
            let mut b = vec![0.0f32; d];
            s.roundtrip(&x, &mut a);
            s.roundtrip(&x3, &mut b);
            for i in 0..d {
                assert!(
                    (3.0 * a[i] - b[i]).abs() < 1e-4 * b[i].abs().max(1.0),
                    "{v:?} i={i}"
                );
            }
        }
    }

    #[test]
    fn zero_vector_safe() {
        for v in ALL {
            let s = Stage1::new(Stage1Config::new(v, 64, 2));
            let x = vec![0.0f32; 64];
            let mut out = vec![1.0f32; 64];
            s.roundtrip(&x, &mut out);
            assert!(out.iter().all(|o| o.is_finite()), "{v:?}");
            // rho = 0 → reconstruction must be exactly 0
            assert!(out.iter().all(|&o| o == 0.0), "{v:?}");
        }
    }

    #[test]
    fn f16_path_close_to_f32() {
        let mut rng = Rng::new(6);
        let d = 128;
        let n = 32;
        let x = gen_batch(&mut rng, n, d);
        let xh: Vec<u16> = x.iter().map(|&v| f16::f32_to_f16_bits(v)).collect();
        for v in [Variant::IsoFull, Variant::IsoFast, Variant::Planar2D, Variant::Rotor3D] {
            let s = Stage1::new(Stage1Config::new(v, d, 4));
            let mut out32 = vec![0.0f32; n * d];
            s.roundtrip_batch(&x, &mut out32, n);
            let mut out16 = vec![0u16; n * d];
            s.roundtrip_batch_f16(&xh, &mut out16, n);
            let out16f: Vec<f32> = out16.iter().map(|&h| f16::f16_bits_to_f32(h)).collect();
            // quantization error dominates fp16 rounding: paths agree closely
            let diff = mse(&out32, &out16f);
            assert!(diff < 1e-4, "{v:?}: {diff}");
        }
    }

    #[test]
    fn unfused_matches_fused() {
        let mut rng = Rng::new(7);
        let d = 128;
        for v in [Variant::IsoFull, Variant::IsoFast, Variant::Rotor3D] {
            let cfg = Stage1Config::new(v, d, 4);
            let fused = Stage1::new(cfg.clone());
            let unfused = Stage1Unfused::from_fused(fused.clone());
            let x = rng.gaussian_vec_f32(d);
            let mut a = vec![0.0f32; d];
            fused.roundtrip(&x, &mut a);
            let b = unfused.roundtrip(&x);
            for i in 0..d {
                assert!(
                    (a[i] - b[i]).abs() < 1e-4 * a[i].abs().max(1.0) + 1e-5,
                    "{v:?} i={i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }

    #[test]
    fn rotation_improves_concentrated_blocks() {
        // eq. 40's operational claim (mirrors the python test)
        let mut rng = Rng::new(8);
        let d = 128;
        let n = 512;
        let mut x = vec![0.0f32; n * d];
        for r in 0..n {
            for b in 0..d / 4 {
                let base = rng.gaussian() as f32;
                x[r * d + b * 4] = base;
                x[r * d + b * 4 + 1] = 0.05 * base + 0.01 * rng.gaussian() as f32;
                x[r * d + b * 4 + 2] = 0.03 * base + 0.01 * rng.gaussian() as f32;
                x[r * d + b * 4 + 3] = 0.02 * base + 0.01 * rng.gaussian() as f32;
            }
        }
        let rotated = Stage1::new(Stage1Config::new(Variant::IsoFull, d, 2));
        let ident = Stage1::with_bank(
            Stage1Config::new(Variant::IsoFull, d, 2),
            ParamBank::identity(Variant::IsoFull, d),
        );
        let mut out = vec![0.0f32; n * d];
        rotated.roundtrip_batch(&x, &mut out, n);
        let mse_rot = mse(&x, &out);
        ident.roundtrip_batch(&x, &mut out, n);
        let mse_id = mse(&x, &out);
        assert!(
            mse_rot < mse_id * 0.8,
            "rotation should help concentrated data: {mse_rot} vs {mse_id}"
        );
    }

    #[test]
    fn grouped8_mixes_across_4blocks() {
        // a vector whose energy lives in one 4-lane group should spread
        // into the adjacent group under the 8D two-stage transform —
        // verified via decode of the encoded form being exact roundtrip
        let d = 16;
        let s = Stage1::new(Stage1Config::new(Variant::Grouped8D, d, 4));
        let mut x = vec![0.0f32; d];
        x[0] = 1.0;
        x[1] = -0.5;
        let mut out = vec![0.0f32; d];
        s.roundtrip(&x, &mut out);
        assert!(out.iter().all(|o| o.is_finite()));
        let rel = mse(&x, &out) / (x.iter().map(|&v| (v * v) as f64).sum::<f64>() / d as f64);
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn batch_encode_decode_bit_exact_with_per_vector() {
        let mut rng = Rng::new(10);
        for v in ALL {
            for (d, n) in [(64usize, 9usize), (66, 5)] {
                let s = Stage1::new(Stage1Config::new(v, d, 3));
                let enc = s.encoded_len();
                let x = rng.gaussian_vec_f32(n * d);
                let mut sink = PackedSink::new();
                s.encode_batch(&x, n, &mut sink);
                assert_eq!(sink.len(), n);
                let mut reference = Vec::new();
                for i in 0..n {
                    s.encode(&x[i * d..(i + 1) * d], &mut reference);
                }
                assert_eq!(sink.as_bytes(), &reference[..], "{v:?} d={d} encode");
                let mut out = vec![0.0f32; n * d];
                let mut scratch = BatchScratch::new();
                s.decode_batch(sink.as_bytes(), n, &mut out, &mut scratch);
                let mut want = vec![0.0f32; n * d];
                for i in 0..n {
                    s.decode(&reference[i * enc..(i + 1) * enc], &mut want[i * d..(i + 1) * d]);
                }
                for j in 0..n * d {
                    assert_eq!(
                        out[j].to_bits(),
                        want[j].to_bits(),
                        "{v:?} d={d} decode j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn strided_decode_ignores_gap_bytes() {
        let mut rng = Rng::new(11);
        let d = 64;
        let n = 6;
        let s = Stage1::new(Stage1Config::new(Variant::IsoFull, d, 4));
        let enc = s.encoded_len();
        let x = rng.gaussian_vec_f32(n * d);
        let mut sink = PackedSink::new();
        s.encode_batch(&x, n, &mut sink);
        // re-lay the records with a 13-byte garbage gap between them
        let stride = enc + 13;
        let mut strided = vec![0xABu8; n * stride];
        for i in 0..n {
            strided[i * stride..i * stride + enc].copy_from_slice(sink.encoded(i));
        }
        let mut scratch = BatchScratch::new();
        let mut got = vec![0.0f32; n * d];
        s.decode_batch_strided(&strided, stride, n, &mut got, &mut scratch);
        let mut want = vec![0.0f32; n * d];
        s.decode_batch(sink.as_bytes(), n, &mut want, &mut scratch);
        assert_eq!(got, want);
    }

    #[test]
    fn sink_reuse_across_batches() {
        let mut rng = Rng::new(12);
        let d = 32;
        let s = Stage1::new(Stage1Config::new(Variant::IsoFast, d, 2));
        let mut sink = PackedSink::new();
        let big = rng.gaussian_vec_f32(16 * d);
        s.encode_batch(&big, 16, &mut sink);
        assert_eq!(sink.len(), 16);
        // a smaller follow-up batch must fully replace the previous one
        let small = rng.gaussian_vec_f32(3 * d);
        s.encode_batch(&small, 3, &mut sink);
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.as_bytes().len(), 3 * s.encoded_len());
        let mut direct = Vec::new();
        for i in 0..3 {
            s.encode(&small[i * d..(i + 1) * d], &mut direct);
        }
        assert_eq!(sink.as_bytes(), &direct[..]);
    }

    #[test]
    fn encoded_len_accounting() {
        let s = Stage1::new(Stage1Config::new(Variant::IsoFull, 128, 4));
        assert_eq!(s.encoded_len(), 4 + 64); // f32 norm + 128 codes @ 4 bits
        let s2 = Stage1::new(Stage1Config::new(Variant::IsoFull, 128, 2));
        assert_eq!(s2.encoded_len(), 4 + 32);
        let s3 = Stage1::new(Stage1Config::new(Variant::Rotor3D, 128, 3));
        assert_eq!(s3.encoded_len(), 4 + 48);
    }
}
