//! **Table 3 ablation — block size** (2D / 3D / 4D / 8D grouped):
//! latency + reconstruction MSE on (a) isotropic vectors (the paper's
//! protocol) and (b) block-correlated vectors (where mixing capacity
//! shows up), plus the §5.7 marginal-distribution statistics that
//! explain the MSE ordering.
//!
//! Run: `cargo bench --bench ablation_blocksize`

use isoquant::quant::{mse, Stage1, Stage1Config, Variant};
use isoquant::util::bench::{Bencher, Table};
use isoquant::util::prng::Rng;

fn correlated(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
    // energy concentrated on one coordinate per 4-block
    let mut x = vec![0.0f32; n * d];
    for r in 0..n {
        for b in 0..d / 4 {
            let base = rng.gaussian() as f32;
            x[r * d + b * 4] = base;
            x[r * d + b * 4 + 1] = 0.1 * base + 0.02 * rng.gaussian() as f32;
            x[r * d + b * 4 + 2] = 0.05 * base + 0.02 * rng.gaussian() as f32;
            x[r * d + b * 4 + 3] = 0.02 * base + 0.02 * rng.gaussian() as f32;
        }
    }
    x
}

fn main() {
    let d = 128;
    let batch = 8192;
    let bench = Bencher::default();
    let mut rng = Rng::new(21);
    let iso = rng.gaussian_vec_f32(batch * d);
    let corr = correlated(&mut rng, batch, d);

    println!("== block-size ablation @ d={d}, batch={batch}, f32 ==\n");
    for bits in [2u8, 4] {
        let mut t = Table::new(&[
            "block",
            "variant",
            "us/batch",
            "MSE (isotropic)",
            "MSE (correlated)",
        ]);
        for (label, v) in [
            ("2D", Variant::Planar2D),
            ("3D", Variant::Rotor3D),
            ("4D", Variant::IsoFull),
            ("4D-fast", Variant::IsoFast),
            ("8D", Variant::Grouped8D),
        ] {
            let s = Stage1::new(Stage1Config::new(v, d, bits));
            let mut out = vec![0.0f32; batch * d];
            let r = bench.run(label, || s.roundtrip_batch(&iso, &mut out, batch));
            s.roundtrip_batch(&iso, &mut out, batch);
            let m_iso = mse(&iso, &out);
            s.roundtrip_batch(&corr, &mut out, batch);
            let m_corr = mse(&corr, &out);
            t.row(vec![
                label.to_string(),
                v.name().to_string(),
                format!("{:.1}", r.median_us()),
                format!("{m_iso:.5}"),
                format!("{m_corr:.5}"),
            ]);
        }
        println!("bits = {bits}:");
        t.print();
        println!();
    }

    // §5.7 marginal statistics: P(|z| > 0.9) for rotated coordinates
    println!("== §5.7 marginal extremity of a rotated unit block coordinate ==\n");
    let mut t = Table::new(&["k", "P(|z| > 0.9)", "P(|z| > 0.99)", "law"]);
    let n = 200_000;
    let mut rng = Rng::new(3);
    // k=2: cos(theta); k=4: first coordinate of a Haar quaternion
    let z2: Vec<f64> = (0..n).map(|_| rng.haar_angle().cos() as f64).collect();
    let z4: Vec<f64> = (0..n).map(|_| rng.haar_quaternion()[0] as f64).collect();
    for (k, z, law) in [
        (2usize, &z2, "arcsine (eq. 37) — extreme-heavy"),
        (4, &z4, "(2/pi)sqrt(1-z^2) (eq. 38) — center-heavy"),
    ] {
        let p90 = z.iter().filter(|v| v.abs() > 0.9).count() as f64 / n as f64;
        let p99 = z.iter().filter(|v| v.abs() > 0.99).count() as f64 / n as f64;
        t.row(vec![
            k.to_string(),
            format!("{p90:.4}"),
            format!("{p99:.4}"),
            law.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nreading: 4D blocks put less mass at the quantizer's extremes (§5.7), which is\n\
         why 4D MSE ≤ 3D MSE ≤ 2D MSE at equal bits on isotropic data, while the 8D\n\
         grouped variant buys extra cross-block mixing on correlated data at ~2x the\n\
         rotation cost."
    );
}
