//! Page-gather throughput: tokens/sec reconstructing a cached sequence,
//! comparing the retained per-vector reference path against the
//! page-granular batch path (`Stage1::decode_batch_strided` via
//! `CacheManager::gather_ws`), single-threaded and strip-parallel —
//! reported at the Table-2 sweep points d ∈ {128, 256, 512} × bits ∈
//! {2, 3, 4}.
//!
//! "tok/s" counts *cached tokens reconstructed per second*: one token =
//! `n_layers × n_heads × 2` encoded head vectors decoded into the
//! lane-major gather layout.
//!
//! Run: `cargo bench --bench gather_throughput`

use isoquant::kvcache::{CacheManager, GatherWorkspace, PageConfig};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::bench::{black_box, Bencher, Table};
use isoquant::util::pool::{default_threads, ParallelPolicy};
use isoquant::util::prng::Rng;

const DIMS: [usize; 3] = [128, 256, 512];
const BITS: [u8; 3] = [2, 3, 4];
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const TOKENS: usize = 128;
const TOKENS_PER_PAGE: usize = 16;

fn build_cache(d: usize, bits: u8) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, d, bits));
    let cfg = PageConfig {
        tokens_per_page: TOKENS_PER_PAGE,
        n_layers: N_LAYERS,
        n_heads: N_HEADS,
        d_head: d,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, TOKENS.div_ceil(TOKENS_PER_PAGE) + 1);
    m.start_seq(1).unwrap();
    let mut rng = Rng::new(0xD0 + d as u64 + bits as u64);
    let tok_n = N_LAYERS * N_HEADS * d;
    for _ in 0..TOKENS {
        let k = rng.gaussian_vec_f32(tok_n);
        let v = rng.gaussian_vec_f32(tok_n);
        m.append_token(1, &k, &v).unwrap();
    }
    m
}

fn main() {
    println!(
        "== page gather throughput: per-vector vs batched vs batched+threads ==\n\
         model {N_LAYERS}L x {N_HEADS}H, {TOKENS} cached tokens, \
         {TOKENS_PER_PAGE} tokens/page, IsoQuant-Full, {} cores\n",
        default_threads()
    );
    let mut table = Table::new(&[
        "d",
        "bits",
        "per-vec tok/s",
        "batched tok/s",
        "threads tok/s",
        "batched x",
        "threads x",
    ]);
    let bench = Bencher::quick();
    for d in DIMS {
        for bits in BITS {
            let mut m = build_cache(d, bits);
            let sz = N_LAYERS * N_HEADS * TOKENS * d;
            let mut k_out = vec![0.0f32; sz];
            let mut v_out = vec![0.0f32; sz];
            let mut ws = GatherWorkspace::new();

            let r_ref = bench.run("per-vector", || {
                black_box(m.gather_reference(1, TOKENS, &mut k_out, &mut v_out).unwrap());
            });

            m.parallel = ParallelPolicy::Off;
            let r_batch = bench.run("batched", || {
                black_box(
                    m.gather_ws(1, TOKENS, &mut k_out, &mut v_out, &mut ws)
                        .unwrap(),
                );
            });

            m.parallel = ParallelPolicy::Auto;
            let r_par = bench.run("batched+threads", || {
                black_box(
                    m.gather_ws(1, TOKENS, &mut k_out, &mut v_out, &mut ws)
                        .unwrap(),
                );
            });

            let tps = |median_s: f64| TOKENS as f64 / median_s;
            let (a, b, c) = (
                tps(r_ref.median.as_secs_f64()),
                tps(r_batch.median.as_secs_f64()),
                tps(r_par.median.as_secs_f64()),
            );
            table.row(vec![
                d.to_string(),
                bits.to_string(),
                format!("{a:.0}"),
                format!("{b:.0}"),
                format!("{c:.0}"),
                format!("{:.2}", b / a),
                format!("{:.2}", c / a),
            ]);
        }
    }
    table.print();
    println!(
        "\nbatched = gather_ws with ParallelPolicy::Off (allocation-free strided \
         page decode);\nthreads = ParallelPolicy::Auto across the {} (layer, head) \
         strips.",
        N_LAYERS * N_HEADS
    );
}
