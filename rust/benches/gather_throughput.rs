//! Page-gather throughput: tokens/sec reconstructing a cached sequence,
//! comparing the retained per-vector reference path against the
//! page-granular batch path (`Stage1::decode_batch_strided` via
//! `CacheManager::gather_ws`) under the scalar and SIMD kernel
//! backends, single-threaded and strip-parallel — reported at the
//! Table-2 sweep points d ∈ {128, 256, 512} × bits ∈ {2, 3, 4}.
//!
//! "tok/s" counts *cached tokens reconstructed per second*: one token =
//! `n_layers × n_heads × 2` encoded head vectors decoded into the
//! lane-major gather layout.  "MB/s" is the uncompressed f32 bandwidth
//! that reconstruction produces.
//!
//! Besides the table, the run emits machine-readable
//! `BENCH_stage1.json` (per-point tokens/sec + MB/s for every
//! backend/mode, plus the SIMD-vs-scalar batch speedup) so future PRs
//! can track the perf trajectory.  Cargo runs bench binaries with the
//! package root as working directory, so the file lands at
//! `rust/BENCH_stage1.json`.
//!
//! Run: `cargo bench --bench gather_throughput` (`-- --quick` for the
//! CI smoke subset).

use isoquant::kvcache::{CacheManager, GatherWorkspace, PageConfig, SeqId};
use isoquant::quant::kernels::{KernelBackend, Resolved};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::bench::{black_box, Bencher, Table};
use isoquant::util::json::Json;
use isoquant::util::pool::{default_threads, ParallelPolicy};
use isoquant::util::prng::Rng;

const DIMS: [usize; 3] = [128, 256, 512];
const BITS: [u8; 3] = [2, 3, 4];
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const TOKENS: usize = 128;
const TOKENS_PER_PAGE: usize = 16;
/// decode lanes in the cross-lane shared-prefix scenario
const LANES: usize = 4;

fn build_cache(d: usize, bits: u8, backend: KernelBackend) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, d, bits).with_backend(backend));
    let cfg = PageConfig {
        tokens_per_page: TOKENS_PER_PAGE,
        n_layers: N_LAYERS,
        n_heads: N_HEADS,
        d_head: d,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, TOKENS.div_ceil(TOKENS_PER_PAGE) + 1);
    m.start_seq(1).unwrap();
    let mut rng = Rng::new(0xD0 + d as u64 + bits as u64);
    let tok_n = N_LAYERS * N_HEADS * d;
    for _ in 0..TOKENS {
        let k = rng.gaussian_vec_f32(tok_n);
        let v = rng.gaussian_vec_f32(tok_n);
        m.append_token(1, &k, &v).unwrap();
    }
    m
}

/// `LANES` sequences all caching the same `TOKENS`-token prompt: lane 1
/// encodes it, the rest adopt the published pages, so every full page is
/// shared by all lanes — the decode-batch shape the cross-lane gather
/// dedup targets.
fn build_shared_cache(d: usize, bits: u8, backend: KernelBackend) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, d, bits).with_backend(backend));
    let cfg = PageConfig {
        tokens_per_page: TOKENS_PER_PAGE,
        n_layers: N_LAYERS,
        n_heads: N_HEADS,
        d_head: d,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, TOKENS.div_ceil(TOKENS_PER_PAGE) * LANES + LANES);
    m.prefix_sharing = true;
    let prompt: Vec<i32> = (0..TOKENS as i32).collect();
    let mut rng = Rng::new(0x5A + d as u64 + bits as u64);
    let tok_n = N_LAYERS * N_HEADS * d;
    for lane in 0..LANES {
        let seq = lane as u64 + 1;
        let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
        let fresh = prompt.len() - reuse.tokens;
        let mut k = Vec::with_capacity(fresh * tok_n);
        let mut v = Vec::with_capacity(fresh * tok_n);
        for _ in 0..fresh {
            k.extend_from_slice(&rng.gaussian_vec_f32(tok_n));
            v.extend_from_slice(&rng.gaussian_vec_f32(tok_n));
        }
        m.append_run(seq, &k, &v, fresh).unwrap();
    }
    m
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dims: &[usize] = if quick { &[128] } else { &DIMS };
    let bits_sweep: &[u8] = if quick { &[4] } else { &BITS };
    let simd_name = KernelBackend::Auto.resolve().name().to_string();
    println!(
        "== page gather throughput: per-vector vs batched, scalar vs {simd_name} kernels ==\n\
         model {N_LAYERS}L x {N_HEADS}H, {TOKENS} cached tokens, \
         {TOKENS_PER_PAGE} tokens/page, IsoQuant-Full, {} cores{}\n",
        default_threads(),
        if quick { " (quick subset)" } else { "" }
    );
    let mut table = Table::new(&[
        "d",
        "bits",
        "per-vec tok/s",
        "scalar tok/s",
        "simd tok/s",
        "simd+thr tok/s",
        "simd x scalar",
        "simd MB/s",
    ]);
    let bench = Bencher::quick();
    let mut entries: Vec<Json> = Vec::new();
    for &d in dims {
        for &bits in bits_sweep {
            let mut scalar_cache = build_cache(d, bits, KernelBackend::Scalar);
            let mut simd_cache = build_cache(d, bits, KernelBackend::Auto);
            let sz = N_LAYERS * N_HEADS * TOKENS * d;
            let mut k_out = vec![0.0f32; sz];
            let mut v_out = vec![0.0f32; sz];
            let mut ws = GatherWorkspace::new();
            let uncompressed_bytes = (N_LAYERS * N_HEADS * 2 * d * 4 * TOKENS) as f64;
            let tps = |median_s: f64| TOKENS as f64 / median_s;
            let mbs = |median_s: f64| uncompressed_bytes / median_s / 1e6;

            // baseline: the pre-batch per-vector reference (scalar math)
            let r_ref = bench.run("per-vector", || {
                black_box(
                    scalar_cache
                        .gather_reference(1, TOKENS, &mut k_out, &mut v_out)
                        .unwrap(),
                );
            });
            // batched page decode, scalar kernels
            scalar_cache.parallel = ParallelPolicy::Off;
            let r_scalar = bench.run("batched-scalar", || {
                black_box(
                    scalar_cache
                        .gather_ws(1, TOKENS, &mut k_out, &mut v_out, &mut ws)
                        .unwrap(),
                );
            });
            // batched page decode, SIMD kernels (the tile path)
            simd_cache.parallel = ParallelPolicy::Off;
            let r_simd = bench.run("batched-simd", || {
                black_box(
                    simd_cache
                        .gather_ws(1, TOKENS, &mut k_out, &mut v_out, &mut ws)
                        .unwrap(),
                );
            });
            // SIMD + strip-parallel threads
            simd_cache.parallel = ParallelPolicy::Auto;
            let r_par = bench.run("batched-simd-threads", || {
                black_box(
                    simd_cache
                        .gather_ws(1, TOKENS, &mut k_out, &mut v_out, &mut ws)
                        .unwrap(),
                );
            });

            let (t_ref, t_scalar, t_simd, t_par) = (
                r_ref.median.as_secs_f64(),
                r_scalar.median.as_secs_f64(),
                r_simd.median.as_secs_f64(),
                r_par.median.as_secs_f64(),
            );
            table.row(vec![
                d.to_string(),
                bits.to_string(),
                format!("{:.0}", tps(t_ref)),
                format!("{:.0}", tps(t_scalar)),
                format!("{:.0}", tps(t_simd)),
                format!("{:.0}", tps(t_par)),
                format!("{:.2}", t_scalar / t_simd),
                format!("{:.0}", mbs(t_simd)),
            ]);
            for (mode, backend, secs) in [
                ("per-vector", "scalar", t_ref),
                ("batched", "scalar", t_scalar),
                ("batched", simd_name.as_str(), t_simd),
                ("batched+threads", simd_name.as_str(), t_par),
            ] {
                entries.push(Json::obj(vec![
                    ("d", Json::num(d as f64)),
                    ("bits", Json::num(bits as f64)),
                    ("mode", Json::str(mode)),
                    ("backend", Json::str(backend)),
                    ("tokens_per_sec", Json::num(tps(secs))),
                    ("mb_per_sec", Json::num(mbs(secs))),
                ]));
            }
            entries.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("bits", Json::num(bits as f64)),
                ("mode", Json::str("speedup")),
                ("backend", Json::str(simd_name.as_str())),
                ("simd_over_scalar_batched", Json::num(t_scalar / t_simd)),
                ("threads_over_scalar_batched", Json::num(t_scalar / t_par)),
            ]));
        }
    }
    table.print();
    println!(
        "\nscalar/simd = gather_ws with ParallelPolicy::Off under KernelBackend \
         Scalar/{simd_name};\nsimd+thr = ParallelPolicy::Auto across the {} (layer, head) \
         strips.",
        N_LAYERS * N_HEADS
    );
    // ---- cross-lane shared-prefix drain: dedup × dtype × backend ----
    println!(
        "\n== cross-lane batched gather: {LANES} lanes sharing one {TOKENS}-token prompt ==\n"
    );
    let mut xlane_backends: Vec<(KernelBackend, String)> = vec![
        (KernelBackend::Scalar, "scalar".to_string()),
        (KernelBackend::Auto, simd_name.clone()),
    ];
    if KernelBackend::Avx512.resolve() == Resolved::Avx512
        && KernelBackend::Auto.resolve() != Resolved::Avx512
    {
        xlane_backends.push((KernelBackend::Avx512, "avx512".to_string()));
    }
    let mut xtable = Table::new(&[
        "d",
        "bits",
        "backend",
        "dedup-off tok/s",
        "dedup-on tok/s",
        "f16-on tok/s",
        "dedup x",
        "on MB/s",
    ]);
    for &d in dims {
        for &bits in bits_sweep {
            for (backend, bname) in &xlane_backends {
                let mut cache = build_shared_cache(d, bits, *backend);
                cache.parallel = ParallelPolicy::Auto;
                let pairs: Vec<(SeqId, usize)> =
                    (0..LANES).map(|lane| (lane as u64 + 1, lane)).collect();
                let sz = N_LAYERS * LANES * N_HEADS * TOKENS * d;
                let mut k_out = vec![0.0f32; sz];
                let mut v_out = vec![0.0f32; sz];
                let mut kh_out = vec![0u16; sz];
                let mut vh_out = vec![0u16; sz];
                let mut ws = GatherWorkspace::new();
                let lane_tokens = (LANES * TOKENS) as f64;
                let tps = |median_s: f64| lane_tokens / median_s;
                let mbs = |median_s: f64, elem: usize| {
                    (N_LAYERS * N_HEADS * 2 * d * elem) as f64 * lane_tokens / median_s / 1e6
                };

                cache.gather_dedup = false;
                let r_off = bench.run("xlane-dedup-off", || {
                    black_box(
                        cache
                            .gather_lanes_into_batch_ws(
                                &pairs, LANES, TOKENS, &mut k_out, &mut v_out, &mut ws,
                            )
                            .unwrap(),
                    );
                });
                cache.gather_dedup = true;
                let r_on = bench.run("xlane-dedup-on", || {
                    black_box(
                        cache
                            .gather_lanes_into_batch_ws(
                                &pairs, LANES, TOKENS, &mut k_out, &mut v_out, &mut ws,
                            )
                            .unwrap(),
                    );
                });
                let r_f16 = bench.run("xlane-dedup-on-f16", || {
                    black_box(
                        cache
                            .gather_lanes_into_batch_f16_ws(
                                &pairs, LANES, TOKENS, &mut kh_out, &mut vh_out, &mut ws,
                            )
                            .unwrap(),
                    );
                });

                let (t_off, t_on, t_f16) = (
                    r_off.median.as_secs_f64(),
                    r_on.median.as_secs_f64(),
                    r_f16.median.as_secs_f64(),
                );
                xtable.row(vec![
                    d.to_string(),
                    bits.to_string(),
                    bname.clone(),
                    format!("{:.0}", tps(t_off)),
                    format!("{:.0}", tps(t_on)),
                    format!("{:.0}", tps(t_f16)),
                    format!("{:.2}", t_off / t_on),
                    format!("{:.0}", mbs(t_on, 4)),
                ]);
                for (dedup, dtype, secs, elem) in [
                    (false, "f32", t_off, 4usize),
                    (true, "f32", t_on, 4),
                    (true, "f16", t_f16, 2),
                ] {
                    entries.push(Json::obj(vec![
                        ("d", Json::num(d as f64)),
                        ("bits", Json::num(bits as f64)),
                        ("mode", Json::str("xlane-batched")),
                        ("backend", Json::str(bname.as_str())),
                        ("lanes", Json::num(LANES as f64)),
                        ("dedup", Json::Bool(dedup)),
                        ("dtype", Json::str(dtype)),
                        ("tokens_per_sec", Json::num(tps(secs))),
                        ("mb_per_sec", Json::num(mbs(secs, elem))),
                    ]));
                }
                entries.push(Json::obj(vec![
                    ("d", Json::num(d as f64)),
                    ("bits", Json::num(bits as f64)),
                    ("mode", Json::str("xlane-speedup")),
                    ("backend", Json::str(bname.as_str())),
                    ("dedup_on_over_off", Json::num(t_off / t_on)),
                    ("f16_over_f32_dedup", Json::num(t_on / t_f16)),
                ]));
            }
        }
    }
    xtable.print();
    println!(
        "\ncross-lane rows drain all {LANES} lanes in one gather_lanes_into_batch call \
         (ParallelPolicy::Auto);\ndedup-on decodes each shared page once and memcpys it \
         into the other lanes."
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("gather_throughput")),
        ("simd_backend", Json::str(simd_name.as_str())),
        ("cores", Json::num(default_threads() as f64)),
        ("tokens", Json::num(TOKENS as f64)),
        ("layers", Json::num(N_LAYERS as f64)),
        ("heads", Json::num(N_HEADS as f64)),
        ("quick", Json::Bool(quick)),
        ("points", Json::Arr(entries)),
    ]);
    match std::fs::write("BENCH_stage1.json", doc.to_string()) {
        Ok(()) => println!("\nwrote BENCH_stage1.json"),
        Err(e) => eprintln!("\ncould not write BENCH_stage1.json: {e}"),
    }
}
