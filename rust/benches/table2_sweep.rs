//! Regenerates paper **Table 2**: the full 18-setting fused-kernel sweep
//! — d ∈ {128, 256, 512} × bits ∈ {2, 3, 4} × dtype ∈ {fp16, fp32},
//! batch 8192 — comparing the fused RotorQuant baseline against
//! IsoQuant-Full / -Fast / -2D, reporting per-setting latency (µs), MSE,
//! and speedups, plus the §9.2/§9.3 aggregates (overall and per-dtype
//! mean speedups, peak settings).
//!
//! Substitution note (DESIGN.md §2): the paper measures fused CUDA
//! kernels on an RTX 4090; this harness measures the equivalent fused
//! native kernels on CPU.  The reproduction target is the *shape* of the
//! comparison (who wins, by roughly what factor, where the best regimes
//! sit), not absolute µs.
//!
//! Run: `cargo bench --bench table2_sweep`

use isoquant::quant::{mse, Stage1, Stage1Config, Variant};
use isoquant::util::bench::{Bencher, Table};
use isoquant::util::f16;
use isoquant::util::prng::Rng;

const BATCH: usize = 8192;
const DIMS: [usize; 3] = [128, 256, 512];
const BITS: [u8; 3] = [2, 3, 4];
const DTYPES: [&str; 2] = ["fp16", "fp32"];
const VARIANTS: [Variant; 4] = [
    Variant::Rotor3D,
    Variant::IsoFull,
    Variant::IsoFast,
    Variant::Planar2D,
];

struct Cell {
    us: f64,
    mse: f64,
}

fn run_cell(variant: Variant, d: usize, bits: u8, dtype: &str, x: &[f32]) -> Cell {
    let stage = Stage1::new(Stage1Config::new(variant, d, bits));
    let bench = Bencher::default();
    if dtype == "fp16" {
        let xh: Vec<u16> = x.iter().map(|&v| f16::f32_to_f16_bits(v)).collect();
        let mut out = vec![0u16; x.len()];
        let r = bench.run("cell", || {
            stage.roundtrip_batch_f16(&xh, &mut out, BATCH);
        });
        stage.roundtrip_batch_f16(&xh, &mut out, BATCH);
        let outf: Vec<f32> = out.iter().map(|&h| f16::f16_bits_to_f32(h)).collect();
        let xf: Vec<f32> = xh.iter().map(|&h| f16::f16_bits_to_f32(h)).collect();
        Cell {
            us: r.median_us(),
            mse: mse(&xf, &outf),
        }
    } else {
        let mut out = vec![0.0f32; x.len()];
        let r = bench.run("cell", || {
            stage.roundtrip_batch(&x, &mut out, BATCH);
        });
        stage.roundtrip_batch(x, &mut out, BATCH);
        Cell {
            us: r.median_us(),
            mse: mse(x, &out),
        }
    }
}

fn main() {
    println!("== Table 2: fused stage-1 sweep vs RotorQuant (batch {BATCH}) ==");
    println!("(CPU substitution for the paper's RTX 4090 fused CUDA kernels — see DESIGN.md)\n");

    let mut table = Table::new(&[
        "dtype", "bits", "dim", "Rotor us", "Full us", "Fast us", "2D us", "Rotor MSE",
        "Full MSE", "Fast MSE", "2D MSE", "Full spd", "Fast spd", "2D spd",
    ]);

    // aggregates keyed per variant: (sum of speedups, count, max, argmax)
    let mut agg: Vec<(f64, usize, f64, String)> =
        vec![(0.0, 0, 0.0, String::new()); 3]; // Full, Fast, 2D
    let mut agg_dtype: Vec<Vec<f64>> = vec![Vec::new(); 6]; // [dtype][variant]

    for (di, dtype) in DTYPES.iter().enumerate() {
        for &bits in &BITS {
            for &d in &DIMS {
                let mut rng = Rng::new(0xD0 + d as u64 + bits as u64);
                let x = rng.gaussian_vec_f32(BATCH * d);
                let cells: Vec<Cell> = VARIANTS
                    .iter()
                    .map(|&v| run_cell(v, d, bits, dtype, &x))
                    .collect();
                let rotor = &cells[0];
                let spd: Vec<f64> = cells[1..].iter().map(|c| rotor.us / c.us).collect();
                for (i, &s) in spd.iter().enumerate() {
                    agg[i].0 += s;
                    agg[i].1 += 1;
                    if s > agg[i].2 {
                        agg[i].2 = s;
                        agg[i].3 = format!("{dtype} b={bits} d={d}");
                    }
                    agg_dtype[di * 3 + i].push(s);
                }
                table.row(vec![
                    dtype.to_string(),
                    bits.to_string(),
                    d.to_string(),
                    format!("{:.1}", rotor.us),
                    format!("{:.1}", cells[1].us),
                    format!("{:.1}", cells[2].us),
                    format!("{:.1}", cells[3].us),
                    format!("{:.4}", rotor.mse),
                    format!("{:.4}", cells[1].mse),
                    format!("{:.4}", cells[2].mse),
                    format!("{:.4}", cells[3].mse),
                    format!("{:.2}", spd[0]),
                    format!("{:.2}", spd[1]),
                    format!("{:.2}", spd[2]),
                ]);
            }
        }
    }
    table.print();

    println!("\n== §9.2/§9.3 aggregates ==");
    let names = ["IsoQuant-Full", "IsoQuant-Fast", "IsoQuant-2D"];
    let paper_mean = [4.49, 4.66, 4.66];
    for i in 0..3 {
        let mean = agg[i].0 / agg[i].1 as f64;
        println!(
            "{:14}: mean speedup {:.2}x (paper: {:.2}x on RTX 4090), peak {:.2}x at {}",
            names[i], mean, paper_mean[i], agg[i].2, agg[i].3
        );
    }
    for (di, dtype) in DTYPES.iter().enumerate() {
        for i in 0..3 {
            let v = &agg_dtype[di * 3 + i];
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            println!("  {dtype} {}: mean {:.2}x over {} settings", names[i], mean, v.len());
        }
    }
    println!(
        "\nshape checks: every IsoQuant variant should beat RotorQuant in every setting;\n\
         MSE columns should be comparable at equal bit width (2D slightly higher — the\n\
         arcsine-vs-semicircle marginal effect of paper §5.7)."
    );
}
