//! Regenerates paper **Table 1** (forward rotation complexity at d=128)
//! from the analytical cost model, extends it across the paper's other
//! dims, and validates the model against *measured* arithmetic
//! throughput: FMAs/µs must be roughly constant across the blockwise
//! variants if the FMA counts explain the latency ordering.
//!
//! Run: `cargo bench --bench table1_complexity`

use isoquant::quant::cost::{forward_rotation_fmas, table1};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::bench::{Bencher, Table};
use isoquant::util::prng::Rng;

fn main() {
    for d in [128usize, 256, 512] {
        println!("== Table 1 @ d = {d} ==\n");
        let mut t = Table::new(&["Method", "Block Structure", "Params", "FMAs"]);
        for row in table1(d) {
            t.row(vec![
                row.method.to_string(),
                row.block_structure,
                row.params.to_string(),
                row.fmas.to_string(),
            ]);
        }
        t.print();
        println!();
    }

    // empirical validation: measured latency vs modeled FMA count
    println!("== cost-model validation (batch 8192, b=4, f32; full pipeline) ==\n");
    let batch = 8192;
    let bench = Bencher::default();
    let mut t = Table::new(&[
        "variant",
        "d",
        "modeled fwd FMAs/vec",
        "measured us/batch",
        "ns per modeled FMA",
    ]);
    for &d in &[128usize, 256] {
        let mut rng = Rng::new(1);
        let x = rng.gaussian_vec_f32(batch * d);
        let mut out = vec![0.0f32; batch * d];
        for v in [
            Variant::Rotor3D,
            Variant::IsoFull,
            Variant::IsoFast,
            Variant::Planar2D,
        ] {
            let s = Stage1::new(Stage1Config::new(v, d, 4));
            let r = bench.run(v.name(), || s.roundtrip_batch(&x, &mut out, batch));
            let fmas = forward_rotation_fmas(v, d);
            t.row(vec![
                v.name().to_string(),
                d.to_string(),
                fmas.to_string(),
                format!("{:.1}", r.median_us()),
                // ×2: the pipeline does forward + inverse rotation
                format!("{:.3}", r.median_us() * 1e3 / (2.0 * fmas as f64 * batch as f64)),
            ]);
        }
    }
    t.print();
    println!(
        "\n(the last column is roughly flat across blockwise variants when the\n\
         FMA model explains the latency ordering; quantization+norm overhead\n\
         is shared and favors none of them)"
    );
}
