//! **Table 3 ablation — quaternion parameters**: random fixed vs learned
//! normalized (paper §5.5, open question §10.3), across correlation
//! strengths, plus quantizer-family ablation (Lloyd–Max vs uniform).
//!
//! Run: `cargo bench --bench ablation_learned`

use isoquant::quant::learn::{learn, LearnOptions};
use isoquant::quant::{mse, QuantKind, Stage1, Stage1Config, Variant};
use isoquant::util::bench::Table;
use isoquant::util::prng::Rng;

fn correlated(rng: &mut Rng, n: usize, d: usize, rho: f32) -> Vec<f32> {
    let mut x = vec![0.0f32; n * d];
    for r in 0..n {
        for b in 0..d / 4 {
            let base = rng.gaussian() as f32;
            let eps = (1.0 - rho * rho).max(0.0).sqrt();
            x[r * d + b * 4] = base;
            for j in 1..4 {
                x[r * d + b * 4 + j] =
                    rho * base * (1.0 - 0.2 * j as f32) + eps * 0.3 * rng.gaussian() as f32;
            }
        }
    }
    x
}

fn main() {
    let d = 64;
    let (n_train, n_test) = (256usize, 1024usize);
    let mut rng = Rng::new(31);

    println!("== learned vs random rotations (b=2, IsoQuant-Full, d={d}) ==\n");
    let mut t = Table::new(&[
        "correlation",
        "random MSE",
        "learned MSE",
        "held-out gain",
    ]);
    for rho in [0.0f32, 0.3, 0.6, 0.9] {
        let train = correlated(&mut rng, n_train, d, rho);
        let test = correlated(&mut rng, n_test, d, rho);
        let cfg = Stage1Config::new(Variant::IsoFull, d, 2);
        let (learned, _b, _a) = learn(
            cfg.clone(),
            &train,
            n_train,
            &LearnOptions {
                iters: 60,
                ..Default::default()
            },
        );
        let random = Stage1::new(cfg);
        let mut out = vec![0.0f32; test.len()];
        random.roundtrip_batch(&test, &mut out, n_test);
        let m_rand = mse(&test, &out);
        learned.roundtrip_batch(&test, &mut out, n_test);
        let m_learn = mse(&test, &out);
        t.row(vec![
            format!("{rho:.1}"),
            format!("{m_rand:.5}"),
            format!("{m_learn:.5}"),
            format!("{:+.1}%", 100.0 * (1.0 - m_learn / m_rand)),
        ]);
    }
    t.print();

    println!("\n== quantizer family: Lloyd-Max (marginal-matched) vs uniform ==\n");
    let mut t = Table::new(&["variant", "bits", "Lloyd MSE", "uniform MSE", "Lloyd gain"]);
    let batch = 4096;
    let x = rng.gaussian_vec_f32(batch * 128);
    for v in [Variant::IsoFull, Variant::Planar2D, Variant::Rotor3D] {
        for bits in [2u8, 4] {
            let mut cfg = Stage1Config::new(v, 128, bits);
            let lloyd = Stage1::new(cfg.clone());
            cfg.quant = QuantKind::Uniform;
            let unif = Stage1::new(cfg);
            let mut out = vec![0.0f32; x.len()];
            lloyd.roundtrip_batch(&x, &mut out, batch);
            let m_l = mse(&x, &out);
            unif.roundtrip_batch(&x, &mut out, batch);
            let m_u = mse(&x, &out);
            t.row(vec![
                v.name().to_string(),
                bits.to_string(),
                format!("{m_l:.5}"),
                format!("{m_u:.5}"),
                format!("{:+.1}%", 100.0 * (1.0 - m_l / m_u)),
            ]);
        }
    }
    t.print();
    println!(
        "\nreading: learned rotations only pay off on correlated data (paper §10.3's\n\
         conjecture); Lloyd–Max's marginal-matched codebooks beat the uniform grid at\n\
         every bit width, most at b=2."
    );
}
