//! Persist-restart benchmark: what the on-disk page store buys a
//! rebooted server — per-client TTFT proxy (admission + prompt
//! materialization) on a **cold boot** (empty store: every prompt
//! encodes from scratch) vs a **warm boot** (same persist dir, fresh
//! cache: every prompt promotes its pages from disk instead of
//! re-encoding).
//!
//! Every client uses a *distinct* prompt, so intra-boot RAM sharing
//! never kicks in and the measured difference is purely
//! encode-vs-promote — the restart benefit, isolated.  A third row
//! reboots once more with the store already hot in the page cache to
//! show the steady-state restart cost.
//!
//! A second scenario puts the store under a tight byte budget and
//! compares segment compaction on vs off: with it on, the spill worker
//! rescues high-retention-score records out of retiring segments, so a
//! reboot still warm-covers the hot prefix that FIFO retirement would
//! have thrown away (`[cache] compact_threshold`).
//!
//! No PJRT artifacts needed: the bench drives `CacheManager` admission
//! and appends directly (the serving path minus the model step).
//!
//! Besides the table, emits machine-readable `BENCH_persist.json` (one
//! row per boot phase) so future PRs can track the trajectory.  Cargo
//! runs bench binaries with the package root as working directory, so
//! the file lands at `rust/BENCH_persist.json`.
//!
//! Run: `cargo bench --bench persist_restart` (`-- --quick` for the CI
//! smoke subset).

use std::path::{Path, PathBuf};
use std::time::Instant;

use isoquant::kvcache::prefix::SCORE_SCALE;
use isoquant::kvcache::store::record_len;
use isoquant::kvcache::{CacheManager, PageConfig, PageStore, PrefixIndexKind, StoreConfig};
use isoquant::metrics::LatencyRecorder;
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::bench::Table;
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;

const D_HEAD: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const BITS: u8 = 4;
const TOKENS_PER_PAGE: usize = 16;
const PROMPT_LEN: usize = 128; // 8 pages per client
const POOL_PAGES: usize = 4096;

fn mk_cache() -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, D_HEAD, BITS));
    let cfg = PageConfig {
        tokens_per_page: TOKENS_PER_PAGE,
        n_layers: N_LAYERS,
        n_heads: N_HEADS,
        d_head: D_HEAD,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, POOL_PAGES);
    m.prefix_sharing = true;
    m
}

struct BootPoint {
    phase: &'static str,
    ttft_p50_us: f64,
    ttft_mean_us: f64,
    reused_tokens: u64,
    promoted: u64,
    spilled: u64,
    rehydrated: u64,
}

/// One server lifetime: admit `clients` distinct prompts, serve, drop
/// (parking + spilling every prompt page), flush, shut down.
fn run_boot(dir: &Path, clients: usize, phase: &'static str) -> BootPoint {
    let mut m = mk_cache();
    let store = PageStore::open(StoreConfig::for_cache(
        dir.to_path_buf(),
        m.fingerprint(),
        m.page_cfg().page_bytes(),
        0,
    ))
    .expect("open page store");
    m.attach_store(store);
    let tok_n = N_LAYERS * N_HEADS * D_HEAD;
    let mut ttft = LatencyRecorder::new();
    for c in 0..clients {
        let prompt: Vec<i32> = (0..PROMPT_LEN as i32).map(|i| (c as i32) * 10_000 + i).collect();
        // deterministic per-client K/V: identical across boots, the
        // stand-in for a real model's prefix-determined cache
        let mut rng = Rng::new(0xB007 + c as u64);
        let k = rng.gaussian_vec_f32(PROMPT_LEN * tok_n);
        let v = rng.gaussian_vec_f32(PROMPT_LEN * tok_n);
        let seq = c as u64 + 1;
        let t0 = Instant::now();
        assert!(m.can_admit_prompt(&prompt, PROMPT_LEN));
        let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
        let left = PROMPT_LEN - reuse.tokens;
        if left > 0 {
            m.append_run(seq, &k[reuse.tokens * tok_n..], &v[reuse.tokens * tok_n..], left)
                .unwrap();
        }
        ttft.record(t0.elapsed());
        m.drop_seq(seq);
    }
    m.flush_store();
    BootPoint {
        phase,
        ttft_p50_us: ttft.percentile(50.0),
        ttft_mean_us: ttft.mean(),
        reused_tokens: m.share.prefix_hit_tokens,
        promoted: m.share.pages_promoted,
        spilled: m.share.pages_spilled,
        rehydrated: m.share.pages_rehydrated,
    }
}

const HOT_LEN: usize = 64; // 4 pages

struct CompactPoint {
    compact: bool,
    records_compacted: u64,
    segments_compacted: u64,
    warm_reused_tokens: usize,
    warm_promoted: u64,
    gather_identical: bool,
}

/// Tight-budget retirement: a hot 4-page prompt (adopted by 4 clients,
/// so its root pages carry retention scores ≥ 2.0) spills first, then
/// distinct cold prompts churn the store past its byte budget with
/// one-record segments.  With compaction off, FIFO retirement throws
/// the hot records out with their (oldest) segments; with it on, the
/// spill worker rescues records scoring ≥ 2.0 into the active segment
/// before each retirement.  Measures what a warm boot still covers of
/// the hot prompt, and that rescued bytes decode bit-identically to a
/// fresh encode.
fn run_compaction(dir: &Path, compact: bool) -> CompactPoint {
    let tok_n = N_LAYERS * N_HEADS * D_HEAD;
    let hot: Vec<i32> = (0..HOT_LEN as i32).collect();
    let mut hot_rng = Rng::new(0xC0_FFEE);
    let hot_k = hot_rng.gaussian_vec_f32(HOT_LEN * tok_n);
    let hot_v = hot_rng.gaussian_vec_f32(HOT_LEN * tok_n);
    let attach = |m: &mut CacheManager, budget_records: u64| {
        let page_bytes = m.page_cfg().page_bytes();
        let rec = record_len(TOKENS_PER_PAGE, page_bytes) as u64;
        let mut sc = StoreConfig::for_cache(
            dir.to_path_buf(),
            m.fingerprint(),
            page_bytes,
            budget_records * rec,
        );
        sc.segment_bytes = rec; // one record per segment: per-page retirement
        if compact {
            sc = sc.with_compaction(2 * SCORE_SCALE as u32, 1 << 20);
        }
        m.attach_store(PageStore::open(sc).expect("open page store"));
    };

    // writer lifetime: the hot prompt shared by 4 clients, then churn
    let mut m = mk_cache();
    m.index_kind = PrefixIndexKind::Radix;
    attach(&mut m, 6);
    for seq in 1..=4u64 {
        assert!(m.can_admit_prompt(&hot, HOT_LEN));
        let reuse = m.start_seq_with_prompt(seq, &hot).unwrap();
        let left = HOT_LEN - reuse.tokens;
        if left > 0 {
            m.append_run(seq, &hot_k[reuse.tokens * tok_n..], &hot_v[reuse.tokens * tok_n..], left)
                .unwrap();
        }
    }
    for seq in 1..=4u64 {
        m.drop_seq(seq); // the last drop parks + spills the hot pages
    }
    m.flush_store(); // hot records land in the oldest segments
    for c in 0..4u64 {
        let prompt: Vec<i32> = (0..HOT_LEN as i32)
            .map(|i| 50_000 + c as i32 * 1_000 + i)
            .collect();
        let mut rng = Rng::new(0xC01D + c);
        let k = rng.gaussian_vec_f32(HOT_LEN * tok_n);
        let v = rng.gaussian_vec_f32(HOT_LEN * tok_n);
        let seq = 100 + c;
        assert!(m.can_admit_prompt(&prompt, HOT_LEN));
        m.start_seq_with_prompt(seq, &prompt).unwrap();
        m.append_run(seq, &k, &v, HOT_LEN).unwrap();
        m.drop_seq(seq);
        m.flush_store();
    }
    m.note_store_health();
    let records_compacted = m.share.records_compacted;
    let segments_compacted = m.share.segments_compacted;
    drop(m);

    // warm boot with a generous budget: what survived of the hot
    // prefix, and does it decode exactly like a fresh encode?
    let mut w = mk_cache();
    w.index_kind = PrefixIndexKind::Radix;
    attach(&mut w, 10_000);
    let reuse = w.start_seq_with_prompt(1, &hot).unwrap();
    let warm_reused_tokens = reuse.tokens;
    let warm_promoted = w.share.pages_promoted;
    let left = HOT_LEN - reuse.tokens;
    if left > 0 {
        w.append_run(1, &hot_k[reuse.tokens * tok_n..], &hot_v[reuse.tokens * tok_n..], left)
            .unwrap();
    }
    let mut fresh = mk_cache();
    fresh.start_seq_with_prompt(1, &hot).unwrap();
    fresh.append_run(1, &hot_k, &hot_v, HOT_LEN).unwrap();
    let n = N_LAYERS * N_HEADS * HOT_LEN * D_HEAD;
    let (mut ka, mut va) = (vec![0f32; n], vec![0f32; n]);
    let (mut kb, mut vb) = (vec![0f32; n], vec![0f32; n]);
    w.gather_reference(1, HOT_LEN, &mut ka, &mut va).unwrap();
    fresh.gather_reference(1, HOT_LEN, &mut kb, &mut vb).unwrap();
    let gather_identical = ka == kb && va == vb;
    w.drop_seq(1);
    fresh.drop_seq(1);
    CompactPoint {
        compact,
        records_compacted,
        segments_compacted,
        warm_reused_tokens,
        warm_promoted,
        gather_identical,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let clients = if quick { 8 } else { 32 };
    let dir: PathBuf = std::env::temp_dir().join(format!(
        "isoquant-bench-persist-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "== persist restart: {clients} clients × distinct {PROMPT_LEN}-token prompts \
         ({} pages each), store at {}{} ==\n",
        PROMPT_LEN / TOKENS_PER_PAGE,
        dir.display(),
        if quick { " (quick subset)" } else { "" }
    );
    let boots = [
        run_boot(&dir, clients, "cold"),   // empty store: encode everything
        run_boot(&dir, clients, "warm"),   // restart: promote from disk
        run_boot(&dir, clients, "warm+2"), // second restart: page-cache hot
    ];
    let mut table = Table::new(&[
        "boot",
        "ttft p50 us",
        "ttft mean us",
        "reused tok",
        "promoted",
        "spilled",
        "rehydrated",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for b in &boots {
        table.row(vec![
            b.phase.to_string(),
            format!("{:.0}", b.ttft_p50_us),
            format!("{:.0}", b.ttft_mean_us),
            b.reused_tokens.to_string(),
            b.promoted.to_string(),
            b.spilled.to_string(),
            b.rehydrated.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("phase", Json::str(b.phase)),
            ("clients", Json::num(clients as f64)),
            ("ttft_p50_us", Json::num(b.ttft_p50_us)),
            ("ttft_mean_us", Json::num(b.ttft_mean_us)),
            ("reused_tokens", Json::num(b.reused_tokens as f64)),
            ("pages_promoted", Json::num(b.promoted as f64)),
            ("pages_spilled", Json::num(b.spilled as f64)),
            ("pages_rehydrated", Json::num(b.rehydrated as f64)),
        ]));
    }
    table.print();
    let speedup = boots[0].ttft_p50_us / boots[1].ttft_p50_us.max(1e-9);
    println!(
        "\nwarm-boot TTFT speedup vs cold: {speedup:.2}x (cold = stage-1 encode of every \
         prompt page; warm = CRC-verified read + memcpy from the persisted store)"
    );

    // tight-budget compaction point: hot 4-page prompt vs cold churn
    // under a 6-record budget with one-record segments
    println!(
        "\n== segment compaction under a tight budget: {HOT_LEN}-token hot prompt \
         (4 adopters) + 4 cold prompts churning a 6-record budget ==\n"
    );
    let mut comp_table = Table::new(&[
        "compaction",
        "rescued recs",
        "rescued segs",
        "warm hit tok",
        "promoted",
        "gather",
    ]);
    let mut comp_rows: Vec<Json> = Vec::new();
    for compact in [false, true] {
        let cdir = dir.join(if compact { "compact-on" } else { "compact-off" });
        std::fs::create_dir_all(&cdir).expect("create compaction bench dir");
        let p = run_compaction(&cdir, compact);
        comp_table.row(vec![
            if p.compact { "on" } else { "off" }.to_string(),
            p.records_compacted.to_string(),
            p.segments_compacted.to_string(),
            p.warm_reused_tokens.to_string(),
            p.warm_promoted.to_string(),
            if p.gather_identical { "identical" } else { "DIVERGED" }.to_string(),
        ]);
        comp_rows.push(Json::obj(vec![
            ("compaction", Json::Bool(p.compact)),
            ("records_compacted", Json::num(p.records_compacted as f64)),
            ("segments_compacted", Json::num(p.segments_compacted as f64)),
            ("warm_reused_tokens", Json::num(p.warm_reused_tokens as f64)),
            ("pages_promoted", Json::num(p.warm_promoted as f64)),
            ("gather_identical", Json::Bool(p.gather_identical)),
        ]));
    }
    comp_table.print();
    println!(
        "\ncompaction rescues the high-score root records ((reuse+1)/(depth+1) >= 2.0)\n\
         out of retiring segments, so the reboot still covers the hot prefix that plain\n\
         FIFO retirement throws away; rescued bytes decode bit-identically."
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("persist_restart")),
        ("prompt_len", Json::num(PROMPT_LEN as f64)),
        ("tokens_per_page", Json::num(TOKENS_PER_PAGE as f64)),
        ("pool_pages", Json::num(POOL_PAGES as f64)),
        ("quick", Json::Bool(quick)),
        ("warm_speedup_p50", Json::num(speedup)),
        ("boots", Json::Arr(rows)),
        ("compaction_points", Json::Arr(comp_rows)),
    ]);
    match std::fs::write("BENCH_persist.json", doc.to_string()) {
        Ok(()) => println!("\nwrote BENCH_persist.json"),
        Err(e) => eprintln!("\ncould not write BENCH_persist.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
