//! End-to-end serving benchmark: tokens/s and step-latency breakdown of
//! the full stack (PJRT decode + compressed KV cache + scheduler) across
//! stage-1 variants and bit widths — the deployment-level counterpart of
//! Table 2 (what the kernel speedups buy in a real decode loop).
//!
//! Requires `make artifacts`.  Skips (exit 0) when artifacts are absent
//! so `cargo bench` stays green in a fresh checkout.
//!
//! Run: `cargo bench --bench e2e_serving`

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, FinishReason, Request};
use isoquant::metrics::Counters;
use isoquant::quant::Variant;
use isoquant::runtime::ServingModel;
use isoquant::util::bench::Table;
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = isoquant::runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("e2e_serving: artifacts not built (run `make artifacts`) — skipping");
        return Ok(());
    }

    println!("== end-to-end serving: variant x bits (8 requests, 16 new tokens) ==\n");
    let mut t = Table::new(&[
        "variant",
        "bits",
        "gen tok/s",
        "decode p50 us",
        "gather p50 us",
        "append p50 us",
        "kv ratio",
    ]);
    for variant in [Variant::Rotor3D, Variant::IsoFull, Variant::IsoFast, Variant::Planar2D] {
        for bits in [2u8, 4] {
            let model = ServingModel::load(&dir)?;
            let vocab = model.meta.vocab;
            let mut cfg = EngineConfig::default();
            cfg.variant = variant;
            cfg.bits = bits;
            let mut engine = Engine::new(model, cfg)?;
            let mut rng = Rng::new(77);
            for i in 0..8 {
                let plen = 8 + rng.below(24);
                engine.submit(Request::new(
                    i,
                    (0..plen).map(|_| rng.below(vocab) as i32).collect(),
                    16,
                ));
            }
            let t0 = std::time::Instant::now();
            engine.run_to_completion()?;
            let wall = t0.elapsed().as_secs_f64();
            let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
            t.row(vec![
                variant.name().to_string(),
                bits.to_string(),
                format!("{:.1}", decoded as f64 / wall),
                format!("{:.0}", engine.stats.decode_step.percentile(50.0)),
                format!("{:.0}", engine.stats.gather.percentile(50.0)),
                format!("{:.0}", engine.stats.append.percentile(50.0)),
                format!("{:.1}x", engine.stats.counters.compression_ratio()),
            ]);
        }
    }
    t.print();
    println!(
        "\nreading: the decode step (XLA executable) dominates on this CPU testbed; the\n\
         stage-1 variant shows up in the gather/append columns — the fraction the paper's\n\
         kernel-level speedups act on.  On an accelerator the model step shrinks and the\n\
         gather fraction (and hence the IsoQuant advantage) grows."
    );

    churn_scenario(&dir)?;
    Ok(())
}

/// Request-churn scenario: a serving mix where clients vanish
/// mid-decode (cancel), run with tight deadlines (timeout), and arrive
/// in bursts beyond the admission bound (shed) — measuring that the
/// lifecycle machinery holds sustained throughput for the survivors
/// and accounting the shed/cancel/timeout rates.  Emits
/// `BENCH_serve.json`.
fn churn_scenario(dir: &std::path::Path) -> anyhow::Result<()> {
    println!("\n== request churn: cancels + deadlines + shed bursts ==\n");
    let model = ServingModel::load(dir)?;
    let vocab = model.meta.vocab;
    let mut engine = Engine::new(model, EngineConfig::default())?;
    let mut rng = Rng::new(0xC0FFEE);

    const N: u64 = 32;
    const MAX_NEW: usize = 16;
    let mut submitted = 0u64;
    let mut prompt = |rng: &mut Rng| -> Vec<i32> {
        let plen = 8 + rng.below(24);
        (0..plen).map(|_| rng.below(vocab) as i32).collect()
    };
    for i in 0..N {
        let mut req = Request::new(i, prompt(&mut rng), MAX_NEW);
        if i % 4 == 3 {
            // every 4th request runs with a deadline too tight for a
            // full decode on this testbed
            req.deadline_ms = Some(20);
        }
        engine.submit(req);
        submitted += 1;
    }
    // ids that will be cancelled mid-flight (client vanished)
    let mut to_cancel: Vec<u64> = (0..N).filter(|i| i % 5 == 0).collect();
    to_cancel.reverse();

    let t0 = std::time::Instant::now();
    let mut steps = 0u64;
    let mut completions = Vec::new();
    loop {
        let worked = engine.step()?;
        completions.extend(engine.take_completions());
        steps += 1;
        // a disconnect arrives every few scheduler iterations
        if steps % 6 == 0 {
            if let Some(id) = to_cancel.pop() {
                engine.cancel(id);
            }
        }
        if !worked && engine.pending() == 0 && engine.active() == 0 {
            break;
        }
    }
    // an overload burst arriving at drain time: every queued request is
    // shed with a definitive rejection instead of hanging (the server's
    // bounded-queue path sheds through the same accounting)
    for i in 0..8u64 {
        engine.submit(Request::new(1_000 + i, prompt(&mut rng), MAX_NEW));
        submitted += 1;
    }
    engine.shed_waiting();
    completions.extend(engine.take_completions());
    // cancels scheduled after the work drained are no-ops, not errors
    let cancelled = engine.cache.share.requests_cancelled;
    let timed_out = engine.cache.share.requests_timed_out;
    let shed = engine.cache.share.requests_shed;
    let wall = t0.elapsed().as_secs_f64();
    let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
    let ok = completions
        .iter()
        .filter(|c| c.finish == FinishReason::MaxTokens)
        .count();

    let mut t = Table::new(&["submitted", "ok", "cancelled", "timeout", "shed", "gen tok/s"]);
    t.row(vec![
        submitted.to_string(),
        ok.to_string(),
        cancelled.to_string(),
        timed_out.to_string(),
        shed.to_string(),
        format!("{:.1}", decoded as f64 / wall),
    ]);
    t.print();
    println!(
        "\nreading: cancelled lanes free their pages immediately (no decode for dead\n\
         sockets), expired deadlines return partial output, and shed bursts never touch\n\
         a lane — survivor throughput is the number to watch."
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("e2e_serving_churn")),
        ("submitted", Json::num(submitted as f64)),
        ("completed_ok", Json::num(ok as f64)),
        ("cancelled", Json::num(cancelled as f64)),
        ("timed_out", Json::num(timed_out as f64)),
        ("shed", Json::num(shed as f64)),
        ("cancel_rate", Json::num(cancelled as f64 / submitted as f64)),
        ("timeout_rate", Json::num(timed_out as f64 / submitted as f64)),
        ("shed_rate", Json::num(shed as f64 / submitted as f64)),
        ("gen_tok_per_s", Json::num(decoded as f64 / wall)),
        ("steps", Json::num(steps as f64)),
    ]);
    match std::fs::write("BENCH_serve.json", doc.to_string()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("\ncould not write BENCH_serve.json: {e}"),
    }
    Ok(())
}
