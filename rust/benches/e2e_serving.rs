//! End-to-end serving benchmark: tokens/s and step-latency breakdown of
//! the full stack (PJRT decode + compressed KV cache + scheduler) across
//! stage-1 variants and bit widths, plus a trace-driven TCP load harness
//! against the reactor front end — four trace mixes (multi-turn chat,
//! RAG, agent-loop bursts, adversarial cache-busting) and a
//! connection-churn sweep at hundreds-to-thousands of concurrent
//! connections, measuring client-side TTFT and inter-token latency as
//! p50/p95/p99 distributions (not throughput scalars) into
//! `BENCH_serve.json`.
//!
//! Requires `make artifacts`.  Skips (writing a stub JSON) when
//! artifacts are absent so `cargo bench` stays green in a fresh
//! checkout.
//!
//! Run: `cargo bench --bench e2e_serving`           (full sweep)
//!      `cargo bench --bench e2e_serving -- --quick` (CI leg: ≥128
//!       concurrent connections, all four trace mixes)

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, FinishReason, Request};
use isoquant::metrics::prometheus::{lint_exposition, render_prometheus};
use isoquant::metrics::{Counters, LatencyRecorder};
use isoquant::quant::Variant;
use isoquant::runtime::ServingModel;
use isoquant::server::{serve_on, ServeReport};
use isoquant::util::bench::Table;
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let dir = isoquant::runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("e2e_serving: artifacts not built (run `make artifacts`) — skipping");
        let stub = Json::obj(vec![
            ("bench", Json::str("e2e_serving")),
            ("skipped", Json::Bool(true)),
        ]);
        let _ = std::fs::write("BENCH_serve.json", stub.to_string());
        return Ok(());
    }
    raise_nofile_limit();

    let mut doc: Vec<(&str, Json)> = vec![
        ("bench", Json::str("e2e_serving")),
        ("quick", Json::Bool(quick)),
    ];
    if !quick {
        variant_table(&dir)?;
    }
    let churn = churn_scenario(&dir)?;
    doc.push(("churn_engine", churn));
    let prof = profiler_overhead(&dir, quick)?;
    doc.push(("profiler_overhead", prof));
    let traces = serve_traces(&dir, quick)?;
    doc.push(("serve", traces));

    match std::fs::write("BENCH_serve.json", Json::obj(doc).to_string()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("\ncould not write BENCH_serve.json: {e}"),
    }
    Ok(())
}

fn variant_table(dir: &Path) -> anyhow::Result<()> {
    println!("== end-to-end serving: variant x bits (8 requests, 16 new tokens) ==\n");
    let mut t = Table::new(&[
        "variant",
        "bits",
        "gen tok/s",
        "decode p50 us",
        "gather p50 us",
        "append p50 us",
        "kv ratio",
    ]);
    for variant in [Variant::Rotor3D, Variant::IsoFull, Variant::IsoFast, Variant::Planar2D] {
        for bits in [2u8, 4] {
            let model = ServingModel::load(dir)?;
            let vocab = model.meta.vocab;
            let mut cfg = EngineConfig::default();
            cfg.variant = variant;
            cfg.bits = bits;
            let mut engine = Engine::new(model, cfg)?;
            let mut rng = Rng::new(77);
            for i in 0..8 {
                let plen = 8 + rng.below(24);
                engine.submit(Request::new(
                    i,
                    (0..plen).map(|_| rng.below(vocab) as i32).collect(),
                    16,
                ));
            }
            let t0 = std::time::Instant::now();
            engine.run_to_completion()?;
            let wall = t0.elapsed().as_secs_f64();
            let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
            t.row(vec![
                variant.name().to_string(),
                bits.to_string(),
                format!("{:.1}", decoded as f64 / wall),
                format!("{:.0}", engine.stats.decode_step.percentile(50.0)),
                format!("{:.0}", engine.stats.gather.percentile(50.0)),
                format!("{:.0}", engine.stats.append.percentile(50.0)),
                format!("{:.1}x", engine.stats.counters.compression_ratio()),
            ]);
        }
    }
    t.print();
    println!(
        "\nreading: the decode step (XLA executable) dominates on this CPU testbed; the\n\
         stage-1 variant shows up in the gather/append columns — the fraction the paper's\n\
         kernel-level speedups act on.  On an accelerator the model step shrinks and the\n\
         gather fraction (and hence the IsoQuant advantage) grows."
    );
    Ok(())
}

/// Request-churn scenario: a serving mix where clients vanish
/// mid-decode (cancel), run with tight deadlines (timeout), and arrive
/// in bursts beyond the admission bound (shed) — measuring that the
/// lifecycle machinery holds sustained throughput for the survivors
/// and accounting the shed/cancel/timeout rates.  Engine-level (no
/// sockets); the TCP counterpart is [`serve_traces`].
fn churn_scenario(dir: &Path) -> anyhow::Result<Json> {
    println!("\n== request churn: cancels + deadlines + shed bursts ==\n");
    let model = ServingModel::load(dir)?;
    let vocab = model.meta.vocab;
    let mut engine = Engine::new(model, EngineConfig::default())?;
    let mut rng = Rng::new(0xC0FFEE);

    const N: u64 = 32;
    const MAX_NEW: usize = 16;
    let mut submitted = 0u64;
    let mut prompt = |rng: &mut Rng| -> Vec<i32> {
        let plen = 8 + rng.below(24);
        (0..plen).map(|_| rng.below(vocab) as i32).collect()
    };
    for i in 0..N {
        let mut req = Request::new(i, prompt(&mut rng), MAX_NEW);
        if i % 4 == 3 {
            // every 4th request runs with a deadline too tight for a
            // full decode on this testbed
            req.deadline_ms = Some(20);
        }
        engine.submit(req);
        submitted += 1;
    }
    // ids that will be cancelled mid-flight (client vanished)
    let mut to_cancel: Vec<u64> = (0..N).filter(|i| i % 5 == 0).collect();
    to_cancel.reverse();

    let t0 = std::time::Instant::now();
    let mut steps = 0u64;
    let mut completions = Vec::new();
    loop {
        let worked = engine.step()?;
        completions.extend(engine.take_completions());
        steps += 1;
        // a disconnect arrives every few scheduler iterations
        if steps % 6 == 0 {
            if let Some(id) = to_cancel.pop() {
                engine.cancel(id);
            }
        }
        if !worked && engine.pending() == 0 && engine.active() == 0 {
            break;
        }
    }
    // an overload burst arriving at drain time: every queued request is
    // shed with a definitive rejection instead of hanging (the server's
    // bounded-queue path sheds through the same accounting)
    for i in 0..8u64 {
        engine.submit(Request::new(1_000 + i, prompt(&mut rng), MAX_NEW));
        submitted += 1;
    }
    engine.shed_waiting();
    completions.extend(engine.take_completions());
    // cancels scheduled after the work drained are no-ops, not errors
    let cancelled = engine.cache.share.requests_cancelled;
    let timed_out = engine.cache.share.requests_timed_out;
    let shed = engine.cache.share.requests_shed;
    let wall = t0.elapsed().as_secs_f64();
    let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
    let ok = completions
        .iter()
        .filter(|c| c.finish == FinishReason::MaxTokens)
        .count();

    let mut t = Table::new(&["submitted", "ok", "cancelled", "timeout", "shed", "gen tok/s"]);
    t.row(vec![
        submitted.to_string(),
        ok.to_string(),
        cancelled.to_string(),
        timed_out.to_string(),
        shed.to_string(),
        format!("{:.1}", decoded as f64 / wall),
    ]);
    t.print();
    println!(
        "\nreading: cancelled lanes free their pages immediately (no decode for dead\n\
         sockets), expired deadlines return partial output, and shed bursts never touch\n\
         a lane — survivor throughput is the number to watch."
    );

    Ok(Json::obj(vec![
        ("submitted", Json::num(submitted as f64)),
        ("completed_ok", Json::num(ok as f64)),
        ("cancelled", Json::num(cancelled as f64)),
        ("timed_out", Json::num(timed_out as f64)),
        ("shed", Json::num(shed as f64)),
        ("cancel_rate", Json::num(cancelled as f64 / submitted as f64)),
        ("timeout_rate", Json::num(timed_out as f64 / submitted as f64)),
        ("shed_rate", Json::num(shed as f64 / submitted as f64)),
        ("gen_tok_per_s", Json::num(decoded as f64 / wall)),
        ("steps", Json::num(steps as f64)),
    ]))
}

/// Observability-tax measurement: the same fixed decode workload with
/// the step profiler off vs on, where the "on" run also renders the
/// full Prometheus exposition at the serve loop's ~1 Hz cadence
/// (approximated as every 64 steps).  The acceptance bar for the
/// observability layer is < 3% tokens/s — but this is a shared CPU
/// testbed, so each arm runs `reps` times and the best run represents
/// it (noise pushes tok/s down, never up).
fn profiler_overhead(dir: &Path, quick: bool) -> anyhow::Result<Json> {
    println!("\n== profiler + metrics exposition overhead ==\n");
    let reps = if quick { 1 } else { 2 };
    let mut run = |profile: bool| -> anyhow::Result<f64> {
        let model = ServingModel::load(dir)?;
        let vocab = model.meta.vocab;
        let mut cfg = EngineConfig::default();
        cfg.profile = profile;
        let mut engine = Engine::new(model, cfg)?;
        let mut rng = Rng::new(31);
        for i in 0..16u64 {
            let plen = 8 + rng.below(24);
            engine.submit(Request::new(
                i,
                (0..plen).map(|_| rng.below(vocab) as i32).collect(),
                16,
            ));
        }
        let t0 = Instant::now();
        let mut steps = 0u64;
        loop {
            let worked = engine.step()?;
            engine.take_completions();
            steps += 1;
            if profile && steps % 64 == 0 {
                // the serve loop re-renders the scrape snapshot about
                // once a second; charge that cost to the "on" arm
                let _ = render_prometheus(&engine.metrics_snapshot());
            }
            if !worked && engine.pending() == 0 && engine.active() == 0 {
                break;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        Ok(Counters::get(&engine.stats.counters.tokens_decoded) as f64 / wall)
    };
    let mut best = |profile: bool| -> anyhow::Result<f64> {
        let mut b = 0.0f64;
        for _ in 0..reps {
            b = b.max(run(profile)?);
        }
        Ok(b)
    };
    let off = best(false)?;
    let on = best(true)?;
    let overhead_pct = (off - on) / off * 100.0;

    let mut t = Table::new(&["profile=off tok/s", "profile=on tok/s", "overhead %"]);
    t.row(vec![
        format!("{off:.1}"),
        format!("{on:.1}"),
        format!("{overhead_pct:.2}"),
    ]);
    t.print();
    println!(
        "\nreading: the profiler is six monotonic-clock reads per step and the exposition\n\
         renders from a snapshot off the hot path — the overhead column should sit in the\n\
         noise floor (acceptance: < 3%; negative values are run-to-run noise)."
    );

    Ok(Json::obj(vec![
        ("tok_per_s_off", Json::num(off)),
        ("tok_per_s_on", Json::num(on)),
        ("overhead_pct", Json::num(overhead_pct)),
    ]))
}

// ---------------------------------------------------------------------
// trace-driven TCP load harness
// ---------------------------------------------------------------------

/// Per-request outcome measured at the client.
#[derive(Default)]
struct MixStats {
    ttft_us: Vec<f64>,
    itl_us: Vec<f64>,
    ok: u64,
    shed: u64,
    errors: u64,
    conns: u64,
}

impl MixStats {
    fn merge(&mut self, other: MixStats) {
        self.ttft_us.extend(other.ttft_us);
        self.itl_us.extend(other.itl_us);
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.conns += other.conns;
    }

    fn requests(&self) -> u64 {
        self.ok + self.shed + self.errors
    }
}

fn pcts(samples: &[f64]) -> (f64, f64, f64) {
    let mut r = LatencyRecorder::new();
    for &s in samples {
        r.record_us(s);
    }
    let p = r.percentiles(&[50.0, 95.0, 99.0]);
    (p[0], p[1], p[2])
}

fn pct_json(samples: &[f64]) -> Json {
    let (p50, p95, p99) = pcts(samples);
    let f = |v: f64| Json::num(if v.is_nan() { -1.0 } else { v });
    Json::obj(vec![
        ("n", Json::num(samples.len() as f64)),
        ("p50_us", f(p50)),
        ("p95_us", f(p95)),
        ("p99_us", f(p99)),
    ])
}

/// Connect with retries: a thousand simultaneous connects can outrun
/// the accept backlog; brief refusals are part of the scenario, not a
/// failure.
fn connect_retry(addr: &str) -> Option<TcpStream> {
    for attempt in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                // a hung server must fail the worker, not wedge the bench
                let _ = s.set_read_timeout(Some(Duration::from_secs(300)));
                return Some(s);
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5 + 5 * attempt)),
        }
    }
    None
}

/// One raw-socket `/metrics` scrape, exactly like Prometheus: HTTP GET,
/// read to EOF (the server closes), return the body.
fn scrape_metrics(addr: &str) -> std::io::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(s, "GET /metrics HTTP/1.1\r\nHost: bench\r\n\r\n")?;
    let mut buf = Vec::new();
    s.read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    match text.split_once("\r\n\r\n") {
        Some((head, body)) if head.contains("200") => Ok(body.to_string()),
        _ => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "malformed scrape response",
        )),
    }
}

fn req_line(id: u64, prompt: &[i32], max_new: usize, stream: bool) -> String {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        (
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("max_new_tokens", Json::num(max_new as f64)),
    ];
    if stream {
        fields.push(("stream", Json::Bool(true)));
    }
    Json::obj(fields).to_string()
}

/// One streaming request over an existing connection: returns per-token
/// timings.  A terminal `finish` line is `ok`; an `error` line counts
/// as shed; EOF/garbage is an error.
fn stream_request(
    s: &mut TcpStream,
    r: &mut BufReader<TcpStream>,
    id: u64,
    prompt: &[i32],
    max_new: usize,
    out: &mut MixStats,
) {
    if writeln!(s, "{}", req_line(id, prompt, max_new, true)).is_err() {
        out.errors += 1;
        return;
    }
    let t0 = Instant::now();
    let mut first: Option<Instant> = None;
    let mut last: Option<Instant> = None;
    let mut line = String::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) | Err(_) => {
                out.errors += 1;
                return;
            }
            Ok(_) => {}
        }
        let Ok(v) = Json::parse(line.trim()) else {
            out.errors += 1;
            return;
        };
        if v.get("error").is_some() {
            out.shed += 1;
            return;
        }
        if v.get("finish").is_some() {
            // non-streamed terminal line only (e.g. rejected before any
            // token): TTFT falls back to total latency
            if first.is_none() {
                out.ttft_us.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            out.ok += 1;
            return;
        }
        // token line
        let now = Instant::now();
        match first {
            None => {
                first = Some(now);
                out.ttft_us.push((now - t0).as_secs_f64() * 1e6);
            }
            Some(_) => {
                if let Some(prev) = last {
                    out.itl_us.push((now - prev).as_secs_f64() * 1e6);
                }
            }
        }
        last = Some(now);
    }
}

fn spawn_workers<F>(n: usize, f: F) -> MixStats
where
    F: Fn(usize, &mut MixStats) + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for w in 0..n {
        let f = f.clone();
        let h = std::thread::Builder::new()
            .stack_size(128 * 1024)
            .spawn(move || {
                let mut stats = MixStats::default();
                f(w, &mut stats);
                stats
            })
            .expect("spawn worker");
        handles.push(h);
    }
    let mut total = MixStats::default();
    for h in handles {
        total.merge(h.join().expect("worker panicked"));
    }
    total
}

/// Multi-turn chat: every conversation shares a system prompt, and each
/// turn's prompt is the full growing history — the prefix index should
/// absorb the re-prefill.
fn mix_chat(addr: &str, conversations: usize, turns: usize, vocab: usize) -> MixStats {
    let addr = addr.to_string();
    spawn_workers(conversations, move |w, out| {
        let Some(mut s) = connect_retry(&addr) else {
            out.errors += 1;
            return;
        };
        out.conns += 1;
        let mut r = BufReader::new(s.try_clone().expect("clone"));
        // shared system prompt (identical across conversations)
        let mut history: Vec<i32> = (0..24).map(|t| (t * 7 + 3) % vocab as i32).collect();
        let mut rng = Rng::new(0xCAA7 + w as u64);
        for turn in 0..turns {
            let user: Vec<i32> = (0..6).map(|_| rng.below(vocab) as i32).collect();
            history.extend_from_slice(&user);
            let id = (w * 100 + turn) as u64 + 1;
            let before_ok = out.ok;
            stream_request(&mut s, &mut r, id, &history, 8, out);
            if out.ok == before_ok {
                return; // connection is unusable past a failure
            }
            // fold the (deterministic-enough) reply into the history so
            // the next turn extends the prefix
            history.extend((0..8).map(|t| ((t + turn * 13) % vocab) as i32));
        }
    })
}

/// RAG: one large shared document prefix plus a tiny unique tail per
/// request — the page-sharing sweet spot.
fn mix_rag(addr: &str, conns: usize, per_conn: usize, vocab: usize) -> MixStats {
    let addr = addr.to_string();
    let doc: Arc<Vec<i32>> = Arc::new((0..64).map(|t| (t * 11 + 5) % vocab as i32).collect());
    spawn_workers(conns, move |w, out| {
        let Some(mut s) = connect_retry(&addr) else {
            out.errors += 1;
            return;
        };
        out.conns += 1;
        let mut r = BufReader::new(s.try_clone().expect("clone"));
        let mut rng = Rng::new(0x4A6 + w as u64);
        for k in 0..per_conn {
            let mut prompt = doc.as_ref().clone();
            prompt.extend((0..4).map(|_| rng.below(vocab) as i32));
            let id = (10_000 + w * 100 + k) as u64;
            stream_request(&mut s, &mut r, id, &prompt, 8, out);
        }
    })
}

/// Agent loop: each agent fires a pipelined burst of requests on one
/// connection, waits for all of them, then repeats — responses
/// interleave by line and are routed back by id at the client.
fn mix_agent(addr: &str, agents: usize, burst: usize, rounds: usize, vocab: usize) -> MixStats {
    let addr = addr.to_string();
    spawn_workers(agents, move |w, out| {
        let Some(mut s) = connect_retry(&addr) else {
            out.errors += 1;
            return;
        };
        out.conns += 1;
        let mut r = BufReader::new(s.try_clone().expect("clone"));
        let mut rng = Rng::new(0xA9E7 + w as u64);
        // tool-call scaffold shared across the agent's own burst
        let scaffold: Vec<i32> = (0..16).map(|t| ((t * 3 + w) % vocab) as i32).collect();
        for round in 0..rounds {
            let t0 = Instant::now();
            let mut open: HashMap<u64, (Option<Instant>, Option<Instant>)> = HashMap::new();
            for b in 0..burst {
                let id = (20_000 + w * 1_000 + round * 100 + b) as u64;
                let mut prompt = scaffold.clone();
                prompt.extend((0..4).map(|_| rng.below(vocab) as i32));
                if writeln!(s, "{}", req_line(id, &prompt, 8, true)).is_err() {
                    out.errors += 1;
                    return;
                }
                open.insert(id, (None, None));
            }
            let mut line = String::new();
            while !open.is_empty() {
                line.clear();
                match r.read_line(&mut line) {
                    Ok(0) | Err(_) => {
                        out.errors += open.len() as u64;
                        return;
                    }
                    Ok(_) => {}
                }
                let Ok(v) = Json::parse(line.trim()) else {
                    out.errors += open.len() as u64;
                    return;
                };
                let id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(-1.0) as u64;
                if v.get("error").is_some() {
                    if open.remove(&id).is_some() {
                        out.shed += 1;
                    }
                    continue;
                }
                if v.get("finish").is_some() {
                    if let Some((first, _)) = open.remove(&id) {
                        if first.is_none() {
                            out.ttft_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        }
                        out.ok += 1;
                    }
                    continue;
                }
                let now = Instant::now();
                if let Some(track) = open.get_mut(&id) {
                    if track.0.is_none() {
                        track.0 = Some(now);
                        out.ttft_us.push((now - t0).as_secs_f64() * 1e6);
                    } else if let Some(prev) = track.1 {
                        out.itl_us.push((now - prev).as_secs_f64() * 1e6);
                    }
                    track.1 = Some(now);
                }
            }
        }
    })
}

/// Adversarial cache-busting: every prompt is unique random noise — no
/// prefix ever repeats, so the index and page pool see worst-case
/// pressure.
fn mix_adversarial(addr: &str, conns: usize, per_conn: usize, vocab: usize) -> MixStats {
    let addr = addr.to_string();
    spawn_workers(conns, move |w, out| {
        let Some(mut s) = connect_retry(&addr) else {
            out.errors += 1;
            return;
        };
        out.conns += 1;
        let mut r = BufReader::new(s.try_clone().expect("clone"));
        let mut rng = Rng::new(0xBAD_5EED ^ (w as u64).wrapping_mul(0x9E37_79B9));
        for k in 0..per_conn {
            let plen = 12 + rng.below(20);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            let id = (40_000 + w * 100 + k) as u64;
            stream_request(&mut s, &mut r, id, &prompt, 8, out);
        }
    })
}

/// Connection churn: a fresh connection per request, all workers open
/// simultaneously — the accept path, buffer pool, and route table under
/// maximum turnover.  Non-streaming (byte-compat path).
fn mix_churn(addr: &str, workers: usize, per_worker: usize, vocab: usize) -> MixStats {
    let addr = addr.to_string();
    spawn_workers(workers, move |w, out| {
        let mut rng = Rng::new(0xC4 + w as u64);
        for k in 0..per_worker {
            let Some(mut s) = connect_retry(&addr) else {
                out.errors += 1;
                continue;
            };
            out.conns += 1;
            let mut r = BufReader::new(s.try_clone().expect("clone"));
            let prompt: Vec<i32> = (0..8).map(|_| rng.below(vocab) as i32).collect();
            let id = (60_000 + w * 100 + k) as u64;
            let t0 = Instant::now();
            if writeln!(s, "{}", req_line(id, &prompt, 2, false)).is_err() {
                out.errors += 1;
                continue;
            }
            let mut line = String::new();
            match r.read_line(&mut line) {
                Ok(n) if n > 0 => match Json::parse(line.trim()) {
                    Ok(v) if v.get("finish").is_some() => {
                        out.ttft_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        out.ok += 1;
                    }
                    Ok(v) if v.get("error").is_some() => out.shed += 1,
                    _ => out.errors += 1,
                },
                _ => out.errors += 1,
            }
        }
    })
}

/// Sample this process's CPU time (utime+stime, in seconds) from
/// /proc/self/stat; NaN off Linux.
fn proc_cpu_seconds() -> f64 {
    #[cfg(target_os = "linux")]
    {
        if let Ok(stat) = std::fs::read_to_string("/proc/self/stat") {
            // fields after the parenthesised comm; utime/stime are
            // fields 14/15 (1-based), i.e. 11/12 after the comm
            if let Some(close) = stat.rfind(')') {
                let f: Vec<&str> = stat[close + 1..].split_whitespace().collect();
                if f.len() > 12 {
                    let utime: f64 = f[11].parse().unwrap_or(0.0);
                    let stime: f64 = f[12].parse().unwrap_or(0.0);
                    return (utime + stime) / 100.0; // USER_HZ = 100
                }
            }
        }
        f64::NAN
    }
    #[cfg(not(target_os = "linux"))]
    {
        f64::NAN
    }
}

/// Raise the fd soft limit to the hard limit (the 1024-connection churn
/// mix holds >2k fds in this one process).  Best-effort; the worker
/// pool degrades gracefully if connects still fail.
fn raise_nofile_limit() {
    #[cfg(target_os = "linux")]
    {
        #[repr(C)]
        struct RLimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
            fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
        }
        const RLIMIT_NOFILE: i32 = 7;
        unsafe {
            let mut r = RLimit { cur: 0, max: 0 };
            if getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur < r.max {
                let want = RLimit { cur: r.max, max: r.max };
                let _ = setrlimit(RLIMIT_NOFILE, &want);
            }
        }
    }
}

fn serve_traces(dir: &Path, quick: bool) -> anyhow::Result<Json> {
    println!("\n== trace-driven load harness (reactor front end) ==\n");
    // prefix sharing + radix index on: chat/RAG mixes are exactly the
    // workloads the cache-aware path exists for
    let mut cfg = EngineConfig::default();
    cfg.prefix_sharing = true;
    cfg.prefix_index = isoquant::kvcache::PrefixIndexKind::Radix;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let dir = dir.to_path_buf();
    // the PJRT client is not Send: the engine must be built on the
    // thread that will run it; vocab comes back over a channel
    let (meta_tx, meta_rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        let model = ServingModel::load(&dir).expect("load model");
        let _ = meta_tx.send(model.meta.vocab);
        let engine = Engine::new(model, cfg).expect("boot engine");
        serve_on(engine, listener, stop_srv)
    });
    let vocab = meta_rx.recv().expect("server failed to boot");

    // idle-CPU check first, while no connection exists: the reactor
    // blocks in epoll and the engine loop blocks on its channel, so a
    // fully idle server should burn ~no CPU (the old loop's 200 µs poll
    // did not)
    let idle_window = Duration::from_millis(if quick { 500 } else { 1500 });
    let cpu0 = proc_cpu_seconds();
    std::thread::sleep(idle_window);
    let idle_cpu_frac = (proc_cpu_seconds() - cpu0) / idle_window.as_secs_f64();
    println!("idle CPU fraction (no connections): {idle_cpu_frac:.4}\n");

    // a Prometheus stand-in scrapes /metrics throughout the load: the
    // scrape must stay fast (it reads a pre-rendered snapshot, never
    // the engine) and every body must lint as valid exposition
    let scrape_stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let addr = addr.clone();
        let stop = scrape_stop.clone();
        std::thread::spawn(move || {
            let mut lat_us: Vec<f64> = Vec::new();
            let mut lint_err: Option<String> = None;
            while !stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                if let Ok(body) = scrape_metrics(&addr) {
                    lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                    if lint_err.is_none() {
                        lint_err = lint_exposition(&body).err();
                    }
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            (lat_us, lint_err)
        })
    };

    let churn_workers = if quick { 128 } else { 1024 };
    let mixes: Vec<(&str, MixStats)> = vec![
        (
            "chat",
            if quick {
                mix_chat(&addr, 4, 3, vocab)
            } else {
                mix_chat(&addr, 16, 4, vocab)
            },
        ),
        (
            "rag",
            if quick {
                mix_rag(&addr, 8, 2, vocab)
            } else {
                mix_rag(&addr, 64, 2, vocab)
            },
        ),
        (
            "agent",
            if quick {
                mix_agent(&addr, 4, 4, 1, vocab)
            } else {
                mix_agent(&addr, 16, 4, 2, vocab)
            },
        ),
        (
            "adversarial",
            if quick {
                mix_adversarial(&addr, 8, 2, vocab)
            } else {
                mix_adversarial(&addr, 64, 2, vocab)
            },
        ),
        ("churn", mix_churn(&addr, churn_workers, 1, vocab)),
    ];

    let mut t = Table::new(&[
        "mix",
        "conns",
        "reqs",
        "ok",
        "shed",
        "err",
        "ttft p50/p95/p99 ms",
        "itl p50/p95/p99 ms",
    ]);
    let mut mix_json: Vec<(&str, Json)> = Vec::new();
    for (name, m) in &mixes {
        let (t50, t95, t99) = pcts(&m.ttft_us);
        let (i50, i95, i99) = pcts(&m.itl_us);
        t.row(vec![
            name.to_string(),
            m.conns.to_string(),
            m.requests().to_string(),
            m.ok.to_string(),
            m.shed.to_string(),
            m.errors.to_string(),
            format!("{:.1}/{:.1}/{:.1}", t50 / 1e3, t95 / 1e3, t99 / 1e3),
            format!("{:.1}/{:.1}/{:.1}", i50 / 1e3, i95 / 1e3, i99 / 1e3),
        ]);
        mix_json.push((
            *name,
            Json::obj(vec![
                ("connections", Json::num(m.conns as f64)),
                ("requests", Json::num(m.requests() as f64)),
                ("ok", Json::num(m.ok as f64)),
                ("shed", Json::num(m.shed as f64)),
                ("errors", Json::num(m.errors as f64)),
                ("ttft_us", pct_json(&m.ttft_us)),
                ("inter_token_us", pct_json(&m.itl_us)),
            ]),
        ));
    }
    t.print();
    println!(
        "\nreading: TTFT under the churn mix is the reactor's accept-to-lane path; the\n\
         chat/RAG curves show what the prefix index buys once the document is resident.\n\
         Latency is reported as a distribution so scheduling PRs diff against the tail,\n\
         not an average."
    );

    scrape_stop.store(true, Ordering::SeqCst);
    let (scrape_lat_us, scrape_lint_err) = scraper.join().expect("scraper panicked");
    let (s50, _, s99) = pcts(&scrape_lat_us);
    println!(
        "\nscrapes under load: {} ({} lint), latency p50/p99 {:.1}/{:.1} ms",
        scrape_lat_us.len(),
        match &scrape_lint_err {
            None => "clean".to_string(),
            Some(e) => format!("FAILED: {e}"),
        },
        s50 / 1e3,
        s99 / 1e3,
    );

    // exercise the stats endpoint and capture the server-side view
    let server_stats = {
        let mut c = isoquant::server::Client::connect(&addr)?;
        c.send_line(r#"{"stats": true}"#)?;
        c.recv()?
    };

    stop.store(true, Ordering::SeqCst);
    let report: ServeReport = server.join().expect("server thread panicked")?;
    println!(
        "server report: requests={} cancelled={} shed={} overflow_disconnects={}",
        report.requests,
        report.share.requests_cancelled,
        report.share.requests_shed,
        report.conn_overflow_disconnects,
    );
    let definitive: u64 = mixes.iter().map(|(_, m)| m.ok + m.shed).sum();
    let errors: u64 = mixes.iter().map(|(_, m)| m.errors).sum();
    if errors > 0 {
        println!("NOTE: {errors} request(s) ended without a definitive line (see errors column)");
    }

    Ok(Json::obj(vec![
        ("idle_cpu_frac", Json::num(idle_cpu_frac)),
        ("churn_connections", Json::num(churn_workers as f64)),
        ("definitive_outcomes", Json::num(definitive as f64)),
        ("client_errors", Json::num(errors as f64)),
        ("server_requests", Json::num(report.requests as f64)),
        (
            "conn_overflow_disconnects",
            Json::num(report.conn_overflow_disconnects as f64),
        ),
        (
            "scrape",
            Json::obj(vec![
                ("scrapes", Json::num(scrape_lat_us.len() as f64)),
                ("latency_us", pct_json(&scrape_lat_us)),
                ("lint_clean", Json::Bool(scrape_lint_err.is_none())),
            ]),
        ),
        ("mixes", Json::obj(mix_json)),
        ("server_stats", server_stats),
    ]))
}
