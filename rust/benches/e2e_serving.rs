//! End-to-end serving benchmark: tokens/s and step-latency breakdown of
//! the full stack (PJRT decode + compressed KV cache + scheduler) across
//! stage-1 variants and bit widths — the deployment-level counterpart of
//! Table 2 (what the kernel speedups buy in a real decode loop).
//!
//! Requires `make artifacts`.  Skips (exit 0) when artifacts are absent
//! so `cargo bench` stays green in a fresh checkout.
//!
//! Run: `cargo bench --bench e2e_serving`

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, Request};
use isoquant::metrics::Counters;
use isoquant::quant::Variant;
use isoquant::runtime::ServingModel;
use isoquant::util::bench::Table;
use isoquant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = isoquant::runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("e2e_serving: artifacts not built (run `make artifacts`) — skipping");
        return Ok(());
    }

    println!("== end-to-end serving: variant x bits (8 requests, 16 new tokens) ==\n");
    let mut t = Table::new(&[
        "variant",
        "bits",
        "gen tok/s",
        "decode p50 us",
        "gather p50 us",
        "append p50 us",
        "kv ratio",
    ]);
    for variant in [Variant::Rotor3D, Variant::IsoFull, Variant::IsoFast, Variant::Planar2D] {
        for bits in [2u8, 4] {
            let model = ServingModel::load(&dir)?;
            let vocab = model.meta.vocab;
            let mut cfg = EngineConfig::default();
            cfg.variant = variant;
            cfg.bits = bits;
            let mut engine = Engine::new(model, cfg)?;
            let mut rng = Rng::new(77);
            for i in 0..8 {
                let plen = 8 + rng.below(24);
                engine.submit(Request {
                    id: i,
                    prompt: (0..plen).map(|_| rng.below(vocab) as i32).collect(),
                    max_new_tokens: 16,
                });
            }
            let t0 = std::time::Instant::now();
            engine.run_to_completion()?;
            let wall = t0.elapsed().as_secs_f64();
            let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
            t.row(vec![
                variant.name().to_string(),
                bits.to_string(),
                format!("{:.1}", decoded as f64 / wall),
                format!("{:.0}", engine.stats.decode_step.percentile(50.0)),
                format!("{:.0}", engine.stats.gather.percentile(50.0)),
                format!("{:.0}", engine.stats.append.percentile(50.0)),
                format!("{:.1}x", engine.stats.counters.compression_ratio()),
            ]);
        }
    }
    t.print();
    println!(
        "\nreading: the decode step (XLA executable) dominates on this CPU testbed; the\n\
         stage-1 variant shows up in the gather/append columns — the fraction the paper's\n\
         kernel-level speedups act on.  On an accelerator the model step shrinks and the\n\
         gather fraction (and hence the IsoQuant advantage) grows."
    );
    Ok(())
}
