//! Prefix-reuse benchmark: what page sharing buys a burst of clients
//! whose prompts overlap — admitted lanes under a constrained pool,
//! pages allocated, and a TTFT proxy (admission + prompt-encode wall
//! time), swept over the fraction of the prompt the clients share, with
//! `prefix_sharing` off vs on.
//!
//! No PJRT artifacts needed: the bench drives `CacheManager` admission
//! and appends directly (the serving path minus the model step), with a
//! deterministic prompt→K/V map standing in for the model.
//!
//! Three scenarios:
//!
//! 1. **shared-fraction sweep** — page-aligned shared prefixes, sharing
//!    off vs on (the PR 3 economics, unchanged);
//! 2. **high fan-out, divergent tails** — many clients share a long
//!    stem that ends mid-page and diverge only in the last token:
//!    flat vs radix index (`[cache] prefix_index`), where the radix
//!    tree's sub-page slot-range reuse turns the shared tail slots
//!    into copies instead of re-encodes and keeps divergent tails
//!    open (no per-client seal→CoW page);
//! 3. **walk-depth × fan-out tree shape** — the v1 one-node-per-page
//!    shape (`set_radix_max_run_pages(1)`) vs v2 cross-page runs:
//!    node counts (a multi-page stem collapses into one v2 node), the
//!    read-only `cached_lcp` walk cost, and how an exact repeat of a
//!    fully-sealed prompt lands (whole-page adopts vs slot copies).
//!
//! Besides the tables, emits machine-readable `BENCH_prefix.json` (one
//! row per sweep point × mode) so future PRs can track the trajectory.
//! Cargo runs bench binaries with the package root as working
//! directory, so the file lands at `rust/BENCH_prefix.json`.
//!
//! Run: `cargo bench --bench prefix_reuse` (`-- --quick` for the CI
//! smoke subset).

use std::time::Instant;

use isoquant::kvcache::{CacheManager, PageConfig, PrefixIndexKind};
use isoquant::metrics::LatencyRecorder;
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::bench::Table;
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;

const D_HEAD: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const BITS: u8 = 4;
const TOKENS_PER_PAGE: usize = 16;
const PROMPT_LEN: usize = 128; // 8 pages
const DECODE_BUDGET: usize = 16; // total_len = 144 → 9 pages/client
/// constrained pool for the admitted-lanes metric: ~10 exclusive
/// clients fit; shared-prefix bursts fit many more
const POOL_PAGES: usize = 96;

fn mk_cache(max_pages: usize, sharing: bool, index: PrefixIndexKind) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, D_HEAD, BITS));
    let cfg = PageConfig {
        tokens_per_page: TOKENS_PER_PAGE,
        n_layers: N_LAYERS,
        n_heads: N_HEADS,
        d_head: D_HEAD,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, max_pages);
    m.prefix_sharing = sharing;
    m.index_kind = index;
    m
}

struct SweepPoint {
    hit_pct: usize,
    sharing: bool,
    admitted: usize,
    pages_after_prompts: usize,
    high_water: usize,
    ttft_p50_us: f64,
    prefix_hit_pages: u64,
    cow_copies: u64,
    bytes_deduped: u64,
}

/// Admit up to `clients` requests whose prompts share the leading
/// `shared_len` tokens, appending each prompt's non-reused remainder
/// (the work on the TTFT path).  Returns the sweep-point measurements.
fn run_burst(clients: usize, shared_len: usize, sharing: bool) -> SweepPoint {
    let mut m = mk_cache(POOL_PAGES, sharing, PrefixIndexKind::Flat);
    let tok_n = N_LAYERS * N_HEADS * D_HEAD;
    // the shared prefix K/V, generated once (a real model produces
    // identical K/V for identical prefixes)
    let mut rng = Rng::new(0x9_1234 + shared_len as u64);
    let shared_k = rng.gaussian_vec_f32(shared_len * tok_n);
    let shared_v = rng.gaussian_vec_f32(shared_len * tok_n);
    let shared_toks: Vec<i32> = (0..shared_len as i32).collect();

    let mut ttft = LatencyRecorder::new();
    let mut admitted = 0usize;
    for c in 0..clients {
        // unique per-client suffix completes the prompt
        let mut prompt = shared_toks.clone();
        prompt.extend((0..PROMPT_LEN - shared_len).map(|i| 10_000 + (c * 1000 + i) as i32));
        let suffix_k = rng.gaussian_vec_f32((PROMPT_LEN - shared_len) * tok_n);
        let suffix_v = rng.gaussian_vec_f32((PROMPT_LEN - shared_len) * tok_n);

        let t0 = Instant::now();
        if !m.can_admit_prompt(&prompt, PROMPT_LEN + DECODE_BUDGET) {
            continue; // pool full: lane not admitted
        }
        let seq = c as u64 + 1;
        let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
        // append the tokens adoption didn't cover: first any shared
        // tokens this client re-encodes (cold client), then its suffix
        let n_shared_left = shared_len.saturating_sub(reuse.tokens);
        if n_shared_left > 0 {
            m.append_run(
                seq,
                &shared_k[reuse.tokens * tok_n..],
                &shared_v[reuse.tokens * tok_n..],
                n_shared_left,
            )
            .unwrap();
        }
        let n_suffix = PROMPT_LEN - reuse.tokens.max(shared_len);
        if n_suffix > 0 {
            let off = (PROMPT_LEN - shared_len - n_suffix) * tok_n;
            m.append_run(
                seq,
                &suffix_k[off..],
                &suffix_v[off..],
                n_suffix,
            )
            .unwrap();
        }
        ttft.record(t0.elapsed());
        admitted += 1;
    }
    SweepPoint {
        hit_pct: shared_len * 100 / PROMPT_LEN,
        sharing,
        admitted,
        pages_after_prompts: m.pages_in_use(),
        high_water: m.high_water_pages(),
        ttft_p50_us: ttft.percentile(50.0),
        prefix_hit_pages: m.share.prefix_hit_pages,
        cow_copies: m.share.cow_copies,
        bytes_deduped: m.share.bytes_deduped,
    }
}

struct FanoutPoint {
    index: PrefixIndexKind,
    admitted: usize,
    pages: usize,
    high_water: usize,
    ttft_p50_us: f64,
    hit_tokens: u64,
    slots_copied: u64,
    tail_copies: u64,
    cow_copies: u64,
}

/// High fan-out scenario: `clients` prompts share a long stem that ends
/// *mid-page* (stem = PROMPT_LEN − 8, i.e. 7 full pages + 8 slots) and
/// diverge only in their final token, then each decodes 2 tokens.  The
/// flat index re-encodes the whole mixed tail page per client and pays
/// a seal→CoW page on the first decode; the radix index copies the 8
/// shared slots, re-encodes 1 token, and keeps the tail open.
fn run_fanout(clients: usize, index: PrefixIndexKind) -> FanoutPoint {
    let stem_len = PROMPT_LEN - 8;
    let decode = 2usize;
    let tok_n = N_LAYERS * N_HEADS * D_HEAD;
    let mut m = mk_cache(POOL_PAGES, true, index);
    let mut rng = Rng::new(0xFA_0427);
    let stem_k = rng.gaussian_vec_f32(stem_len * tok_n);
    let stem_v = rng.gaussian_vec_f32(stem_len * tok_n);
    let stem_toks: Vec<i32> = (0..stem_len as i32).collect();
    let mut ttft = LatencyRecorder::new();
    let mut admitted = 0usize;
    for c in 0..clients {
        let mut prompt = stem_toks.clone();
        prompt.push(20_000 + c as i32); // 1-token divergent tail
        let div_k = rng.gaussian_vec_f32(tok_n);
        let div_v = rng.gaussian_vec_f32(tok_n);
        let t0 = Instant::now();
        if !m.can_admit_prompt(&prompt, prompt.len() + decode) {
            continue;
        }
        let seq = c as u64 + 1;
        let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
        let n_shared_left = stem_len.saturating_sub(reuse.tokens);
        if n_shared_left > 0 {
            m.append_run(
                seq,
                &stem_k[reuse.tokens * tok_n..],
                &stem_v[reuse.tokens * tok_n..],
                n_shared_left,
            )
            .unwrap();
        }
        m.append_run(seq, &div_k, &div_v, 1).unwrap();
        ttft.record(t0.elapsed());
        for _ in 0..decode {
            let dk = rng.gaussian_vec_f32(tok_n);
            let dv = rng.gaussian_vec_f32(tok_n);
            m.append_run(seq, &dk, &dv, 1).unwrap();
        }
        admitted += 1;
    }
    FanoutPoint {
        index,
        admitted,
        pages: m.pages_in_use(),
        high_water: m.high_water_pages(),
        ttft_p50_us: ttft.percentile(50.0),
        hit_tokens: m.share.prefix_hit_tokens,
        slots_copied: m.share.slots_copied,
        tail_copies: m.share.tail_copies,
        cow_copies: m.share.cow_copies,
    }
}

struct WalkPoint {
    shape: &'static str,
    depth: usize,
    fanout: usize,
    nodes_stem: usize,
    nodes_total: usize,
    walk_ns: f64,
    repeat_hit: String,
    repeat_hit_tokens: u64,
}

/// Walk-depth × fan-out scenario: one head client encodes a
/// `depth`-token prompt (the shared stem is all but the final 8
/// tokens, so it ends mid-page), `fanout − 1` followers diverge in
/// those final 8 tokens, then the head prompt is submitted once more
/// verbatim.  Run under both radix tree shapes — v1 one-node-per-page
/// (`set_radix_max_run_pages(1)`) vs v2 cross-page runs — comparing
/// node counts, the read-only `cached_lcp` walk the batcher probes
/// under pool pressure, and whether the exact repeat lands as
/// whole-page adopts or per-slot copies.
fn run_walk(depth: usize, fanout: usize, v1_shape: bool, iters: usize) -> WalkPoint {
    let stem_len = depth - 8;
    let tok_n = N_LAYERS * N_HEADS * D_HEAD;
    let mut m = mk_cache(POOL_PAGES, true, PrefixIndexKind::Radix);
    if v1_shape {
        m.set_radix_max_run_pages(1);
    }
    let mut rng = Rng::new(0x3A1C + depth as u64);
    let stem_toks: Vec<i32> = (0..stem_len as i32).collect();
    let stem_k = rng.gaussian_vec_f32(stem_len * tok_n);
    let stem_v = rng.gaussian_vec_f32(stem_len * tok_n);
    let mut head_prompt: Vec<i32> = Vec::new();
    let mut nodes_stem = 0usize;
    for c in 0..fanout {
        let mut prompt = stem_toks.clone();
        prompt.extend((0..8).map(|i| 30_000 + (c * 100 + i) as i32));
        let tail_k = rng.gaussian_vec_f32(8 * tok_n);
        let tail_v = rng.gaussian_vec_f32(8 * tok_n);
        let seq = c as u64 + 1;
        assert!(m.can_admit_prompt(&prompt, depth));
        let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
        let n_stem_left = stem_len.saturating_sub(reuse.tokens);
        if n_stem_left > 0 {
            m.append_run(
                seq,
                &stem_k[reuse.tokens * tok_n..],
                &stem_v[reuse.tokens * tok_n..],
                n_stem_left,
            )
            .unwrap();
        }
        let covered = reuse.tokens.max(stem_len);
        let off = (covered - stem_len) * tok_n;
        m.append_run(seq, &tail_k[off..], &tail_v[off..], depth - covered)
            .unwrap();
        if c == 0 {
            head_prompt = prompt;
            nodes_stem = m.radix_node_count();
        }
    }
    // exact repeat of the head prompt: every page of it is sealed (the
    // final 8 tokens fill its last page), so the repeat should cost
    // zero slot copies — pure whole-page refcount hits
    let before_copies = m.share.slots_copied;
    let before_hits = m.share.prefix_hit_tokens;
    let reuse = m.start_seq_with_prompt(fanout as u64 + 1, &head_prompt).unwrap();
    assert_eq!(reuse.tokens, depth, "exact repeat must be fully covered");
    let d_copies = m.share.slots_copied - before_copies;
    let repeat_hit = if d_copies == 0 {
        "adopt".to_string()
    } else {
        format!("copy({d_copies})")
    };
    let repeat_hit_tokens = m.share.prefix_hit_tokens - before_hits;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(m.cached_lcp(std::hint::black_box(&head_prompt)));
    }
    let walk_ns = t0.elapsed().as_nanos() as f64 / iters.max(1) as f64;
    WalkPoint {
        shape: if v1_shape { "v1" } else { "v2" },
        depth,
        fanout,
        nodes_stem,
        nodes_total: m.radix_node_count(),
        walk_ns,
        repeat_hit,
        repeat_hit_tokens,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let clients = if quick { 16 } else { 64 };
    let fractions: &[usize] = if quick { &[0, 100] } else { &[0, 25, 50, 75, 100] };
    println!(
        "== prefix reuse: {clients} clients, prompt {PROMPT_LEN} tok ({} pages) + {DECODE_BUDGET} \
         decode budget, pool {POOL_PAGES} pages{} ==\n",
        PROMPT_LEN / TOKENS_PER_PAGE,
        if quick { " (quick subset)" } else { "" }
    );
    let mut table = Table::new(&[
        "shared %",
        "sharing",
        "admitted",
        "pages",
        "hw pages",
        "ttft p50 us",
        "hit pages",
        "cow",
        "dedup MB",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for &pct in fractions {
        // shared prefix rounded down to whole pages (page-granular index)
        let shared_len = (PROMPT_LEN * pct / 100) / TOKENS_PER_PAGE * TOKENS_PER_PAGE;
        for sharing in [false, true] {
            let p = run_burst(clients, shared_len, sharing);
            table.row(vec![
                format!("{}", p.hit_pct),
                if sharing { "on" } else { "off" }.to_string(),
                p.admitted.to_string(),
                p.pages_after_prompts.to_string(),
                p.high_water.to_string(),
                format!("{:.0}", p.ttft_p50_us),
                p.prefix_hit_pages.to_string(),
                p.cow_copies.to_string(),
                format!("{:.1}", p.bytes_deduped as f64 / 1e6),
            ]);
            rows.push(Json::obj(vec![
                ("shared_pct", Json::num(p.hit_pct as f64)),
                ("sharing", Json::Bool(sharing)),
                ("clients", Json::num(clients as f64)),
                ("admitted_lanes", Json::num(p.admitted as f64)),
                ("pages_after_prompts", Json::num(p.pages_after_prompts as f64)),
                ("high_water_pages", Json::num(p.high_water as f64)),
                ("ttft_p50_us", Json::num(p.ttft_p50_us)),
                ("prefix_hit_pages", Json::num(p.prefix_hit_pages as f64)),
                ("cow_copies", Json::num(p.cow_copies as f64)),
                ("bytes_deduped", Json::num(p.bytes_deduped as f64)),
            ]));
        }
    }
    table.print();
    println!(
        "\nadmitted = lanes the pool accepts out of the burst (prefix-aware admission counts\n\
         only new-pages-after-reuse); ttft p50 = admission + prompt-encode wall time per\n\
         admitted client — the pre-first-token work the engine does on the cache path."
    );

    // scenario 2: high fan-out, mid-page stem, 1-token divergent tails —
    // the flat-vs-radix column ([cache] prefix_index)
    println!(
        "\n== high fan-out: {clients} clients, {}-token shared stem (mid-page) + 1-token \
         divergent tails + 2 decode tokens, pool {POOL_PAGES} pages ==\n",
        PROMPT_LEN - 8,
    );
    let mut fan_table = Table::new(&[
        "index",
        "admitted",
        "pages",
        "hw pages",
        "ttft p50 us",
        "hit tok",
        "slot copies",
        "tail copies",
        "cow",
    ]);
    let mut fan_rows: Vec<Json> = Vec::new();
    for index in [PrefixIndexKind::Flat, PrefixIndexKind::Radix] {
        let p = run_fanout(clients, index);
        fan_table.row(vec![
            p.index.name().to_string(),
            p.admitted.to_string(),
            p.pages.to_string(),
            p.high_water.to_string(),
            format!("{:.0}", p.ttft_p50_us),
            p.hit_tokens.to_string(),
            p.slots_copied.to_string(),
            p.tail_copies.to_string(),
            p.cow_copies.to_string(),
        ]);
        fan_rows.push(Json::obj(vec![
            ("index", Json::str(p.index.name())),
            ("clients", Json::num(clients as f64)),
            ("stem_len", Json::num((PROMPT_LEN - 8) as f64)),
            ("admitted_lanes", Json::num(p.admitted as f64)),
            ("pages_in_use", Json::num(p.pages as f64)),
            ("high_water_pages", Json::num(p.high_water as f64)),
            ("ttft_p50_us", Json::num(p.ttft_p50_us)),
            ("prefix_hit_tokens", Json::num(p.hit_tokens as f64)),
            ("slots_copied", Json::num(p.slots_copied as f64)),
            ("tail_copies", Json::num(p.tail_copies as f64)),
            ("cow_copies", Json::num(p.cow_copies as f64)),
        ]));
    }
    fan_table.print();
    println!(
        "\nradix matches the stem at token granularity: followers copy the 8 shared tail\n\
         slots instead of re-encoding them, and their open tails skip the per-client\n\
         seal->CoW page the flat lifecycle pays on the first decode token."
    );

    // scenario 3: walk-depth × fan-out — radix tree shape, v1
    // one-node-per-page vs v2 cross-page runs
    let depths: &[usize] = if quick { &[64] } else { &[64, 128] };
    let fanouts: &[usize] = if quick { &[4] } else { &[4, 16] };
    let iters = if quick { 2_000 } else { 20_000 };
    println!(
        "\n== radix walk: depth × fan-out, v1 one-node-per-page vs v2 cross-page runs \
         (tails diverge in the final 8 tokens) ==\n"
    );
    let mut walk_table = Table::new(&[
        "shape",
        "depth",
        "fanout",
        "stem nodes",
        "nodes",
        "walk ns",
        "repeat hit",
        "repeat tok",
    ]);
    let mut walk_rows: Vec<Json> = Vec::new();
    for &depth in depths {
        for &fanout in fanouts {
            for v1_shape in [true, false] {
                let p = run_walk(depth, fanout, v1_shape, iters);
                walk_table.row(vec![
                    p.shape.to_string(),
                    p.depth.to_string(),
                    p.fanout.to_string(),
                    p.nodes_stem.to_string(),
                    p.nodes_total.to_string(),
                    format!("{:.0}", p.walk_ns),
                    p.repeat_hit.clone(),
                    p.repeat_hit_tokens.to_string(),
                ]);
                walk_rows.push(Json::obj(vec![
                    ("shape", Json::str(p.shape)),
                    ("depth", Json::num(p.depth as f64)),
                    ("fanout", Json::num(p.fanout as f64)),
                    ("stem_nodes", Json::num(p.nodes_stem as f64)),
                    ("nodes_total", Json::num(p.nodes_total as f64)),
                    ("walk_ns", Json::num(p.walk_ns)),
                    ("repeat_hit", Json::str(p.repeat_hit.clone())),
                    ("repeat_hit_tokens", Json::num(p.repeat_hit_tokens as f64)),
                ]));
            }
        }
    }
    walk_table.print();
    println!(
        "\nstem nodes = tree size after the head client alone: a multi-page stem is ONE\n\
         v2 cross-page run vs one node per page under the v1 shape.  walk ns = the\n\
         read-only cached_lcp probe the batcher uses to drain deepest-LCP-first under\n\
         pool pressure; repeat hit = how an exact repeat of the head prompt lands."
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("prefix_reuse")),
        ("prompt_len", Json::num(PROMPT_LEN as f64)),
        ("tokens_per_page", Json::num(TOKENS_PER_PAGE as f64)),
        ("decode_budget", Json::num(DECODE_BUDGET as f64)),
        ("pool_pages", Json::num(POOL_PAGES as f64)),
        ("quick", Json::Bool(quick)),
        ("points", Json::Arr(rows)),
        ("fanout_points", Json::Arr(fan_rows)),
        ("walk_points", Json::Arr(walk_rows)),
    ]);
    match std::fs::write("BENCH_prefix.json", doc.to_string()) {
        Ok(()) => println!("\nwrote BENCH_prefix.json"),
        Err(e) => eprintln!("\ncould not write BENCH_prefix.json: {e}"),
    }
}
