//! Reproduces paper **§9.4**: module-level (unfused, multi-pass,
//! matrix-materializing) vs fused kernel-level execution.
//!
//! The paper reports 4–10× apparent speedups at module level because the
//! unfused baseline pays per-stage buffers and the 8-component
//! multivector expansion; the fused comparison isolates the
//! method-intrinsic advantage.  This bench measures both for each
//! variant so the two claims can be separated, exactly as §9.4 argues.
//!
//! Run: `cargo bench --bench module_vs_kernel`

use isoquant::quant::{Stage1, Stage1Config, Stage1Unfused, Variant};
use isoquant::util::bench::{Bencher, Table};
use isoquant::util::prng::Rng;

fn main() {
    let batch = 4096;
    let bench = Bencher::default();
    println!("== fused kernel vs unfused module path (batch {batch}, b=4, f32) ==\n");
    let mut t = Table::new(&[
        "variant",
        "d",
        "fused us",
        "unfused us",
        "fusion gain",
        "unfused rotor / unfused iso",
        "fused rotor / fused iso",
    ]);
    for &d in &[128usize, 256] {
        let mut rng = Rng::new(5);
        let x = rng.gaussian_vec_f32(batch * d);
        let mut results: Vec<(Variant, f64, f64)> = Vec::new();
        for v in [Variant::Rotor3D, Variant::IsoFull, Variant::IsoFast] {
            let cfg = Stage1Config::new(v, d, 4);
            let fused = Stage1::new(cfg.clone());
            let unfused = Stage1Unfused::from_fused(fused.clone());
            let mut out = vec![0.0f32; batch * d];
            let rf = bench.run("fused", || fused.roundtrip_batch(&x, &mut out, batch));
            let ru = bench.run("unfused", || {
                for i in 0..batch {
                    let y = unfused.roundtrip(&x[i * d..(i + 1) * d]);
                    out[i * d..(i + 1) * d].copy_from_slice(&y);
                }
            });
            results.push((v, rf.median_us(), ru.median_us()));
        }
        let (rotor_f, rotor_u) = (results[0].1, results[0].2);
        for &(v, f, u) in &results {
            t.row(vec![
                v.name().to_string(),
                d.to_string(),
                format!("{f:.1}"),
                format!("{u:.1}"),
                format!("{:.2}x", u / f),
                format!("{:.2}x", rotor_u / u),
                format!("{:.2}x", rotor_f / f),
            ]);
        }
    }
    t.print();
    println!(
        "\nreading: the module-level advantage (unfused rotor / unfused iso) exceeds the\n\
         fused advantage because the rotor module also pays the 8-component multivector\n\
         expansion — the paper's §9.4 'implementation-dependent' component.  The fused\n\
         column is the method-intrinsic claim."
    );
}
