//! Persistent page store integration tests: restart rehydration,
//! RAM→disk demotion + promotion, and every corruption mode degrading
//! to a clean miss.
//!
//! The safety bar throughout: a warm boot must either serve
//! *byte-identical* pages (full record verification passed) or
//! re-encode (miss) — wrong bytes are never an outcome, no matter what
//! happened to the files in between.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use isoquant::kvcache::{chain_key, CacheManager, PageConfig, PageStore, StoreConfig};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::prng::Rng;

const TP: usize = 4;
const D_HEAD: usize = 32;

fn tmpdir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "isoquant-persist-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn mk_cache(max_pages: usize, bits: u8, sharing: bool) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, D_HEAD, bits));
    let cfg = PageConfig {
        tokens_per_page: TP,
        n_layers: 2,
        n_heads: 2,
        d_head: D_HEAD,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, max_pages);
    m.prefix_sharing = sharing;
    m
}

fn attach(m: &mut CacheManager, dir: &Path) {
    let store = PageStore::open(StoreConfig::for_cache(
        dir.to_path_buf(),
        m.fingerprint(),
        m.page_cfg().page_bytes(),
        0, // unlimited budget: these tests exercise verification, not retirement
    ))
    .unwrap();
    m.attach_store(store);
}

/// Deterministic K/V for position `t` of `stream` (same prefix ⇒ same
/// vectors — the property that makes prompt pages shareable and
/// persistable).
fn kv_at(stream: &[i32], t: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let seed = chain_key(None, &stream[..=t], 0xBEEF).0;
    let mut rng = Rng::new(seed);
    let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
    (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
}

fn kv_run(stream: &[i32], from: usize, to: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let (mut k, mut v) = (Vec::new(), Vec::new());
    for t in from..to {
        let (tk, tv) = kv_at(stream, t, cfg);
        k.extend_from_slice(&tk);
        v.extend_from_slice(&tv);
    }
    (k, v)
}

fn gather_bits(m: &CacheManager, seq: u64, t_max: usize) -> (Vec<u32>, Vec<u32>) {
    let cfg = m.page_cfg();
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut k, mut v) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    m.gather(seq, t_max, &mut k, &mut v).unwrap();
    (
        k.iter().map(|x| x.to_bits()).collect(),
        v.iter().map(|x| x.to_bits()).collect(),
    )
}

/// Populate a store: one sequence runs `prompt`, publishes its pages,
/// then drops — parking (and spilling) every prompt page.  Returns the
/// byte-level gather of the prompt region as ground truth.
fn populate(dir: &Path, prompt: &[i32], bits: u8) -> (Vec<u32>, Vec<u32>) {
    let mut m = mk_cache(64, bits, true);
    attach(&mut m, dir);
    let cfg = m.page_cfg();
    m.start_seq_with_prompt(1, prompt).unwrap();
    let (k, v) = kv_run(prompt, 0, prompt.len(), &cfg);
    m.append_run(1, &k, &v, prompt.len()).unwrap();
    let truth = gather_bits(&m, 1, prompt.len());
    m.drop_seq(1);
    m.flush_store();
    truth
}

/// Boot a fresh cache on `dir` and admit `prompt`; return (reused
/// tokens, gather bits over the prompt region after appending whatever
/// reuse did not cover).
fn warm_boot(dir: &Path, prompt: &[i32], bits: u8) -> (usize, (Vec<u32>, Vec<u32>)) {
    let mut m = mk_cache(64, bits, true);
    attach(&mut m, dir);
    let cfg = m.page_cfg();
    assert!(m.can_admit_prompt(prompt, prompt.len()));
    let reuse = m.start_seq_with_prompt(1, prompt).unwrap();
    let (k, v) = kv_run(prompt, reuse.tokens, prompt.len(), &cfg);
    m.append_run(1, &k, &v, prompt.len() - reuse.tokens).unwrap();
    let bits_out = gather_bits(&m, 1, prompt.len());
    // batched path still agrees with the per-vector oracle on
    // promoted pages
    let sz = cfg.n_layers * cfg.n_heads * prompt.len() * cfg.d_head;
    let (mut ko, mut vo) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    m.gather_reference(1, prompt.len(), &mut ko, &mut vo).unwrap();
    assert_eq!(
        bits_out.0,
        ko.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "batched vs reference K gather diverged after promotion"
    );
    assert_eq!(
        bits_out.1,
        vo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "batched vs reference V gather diverged after promotion"
    );
    m.drop_seq(1);
    assert_eq!(m.live_refs(), 0);
    (reuse.tokens, bits_out)
}

fn prompt10() -> Vec<i32> {
    (0..10).map(|i| 100 + i).collect() // 2 full pages + sealed tail of 2
}

fn single_segment(dir: &Path) -> PathBuf {
    let mut segs: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|e| e == "iqs"))
        .collect();
    assert_eq!(segs.len(), 1, "expected exactly one segment: {segs:?}");
    segs.pop().unwrap()
}

#[test]
fn restart_promotes_pages_byte_identical() {
    let dir = tmpdir("restart");
    let prompt = prompt10();
    let truth = populate(&dir, &prompt, 3);

    // warm boot: full-prefix hit served entirely from disk
    let mut m = mk_cache(64, 3, true);
    attach(&mut m, &dir);
    assert_eq!(m.share.pages_rehydrated, 3, "2 full pages + sealed tail");
    assert_eq!(m.cold_pages(), 3);
    assert_eq!(m.prefix_index_len(), 0, "RAM index starts empty");
    let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
    assert_eq!(reuse.tokens, prompt.len(), "no re-encode of the shared prefix");
    assert_eq!(reuse.pages, 3);
    assert_eq!(m.share.pages_promoted, 3);
    assert_eq!(m.prefix_index_len(), 3, "promotions republish to RAM");
    assert_eq!(gather_bits(&m, 1, prompt.len()), truth, "bytes survive the disk roundtrip");

    // a second sequence now warm-hits RAM, not disk
    let reuse2 = m.start_seq_with_prompt(2, &prompt).unwrap();
    assert_eq!(reuse2.tokens, prompt.len());
    assert_eq!(m.share.pages_promoted, 3, "second adoption is a RAM hit");

    // decode appends CoW the promoted tail exactly like a warm one
    let mut stream = prompt.clone();
    for d in 0..3 {
        stream.push(10_000 + d);
        let (k, v) = kv_at(&stream, stream.len() - 1, &m.page_cfg());
        m.append_token(1, &k, &v).unwrap();
    }
    assert_eq!(m.share.cow_copies, 1);
    m.drop_seq(1);
    m.drop_seq(2);
    assert_eq!(m.live_refs(), 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn pool_pressure_demotes_and_promotes_back() {
    // pool of 2 pages: prompt A's pages must be demoted to disk to
    // make room for prompt B, then promoted back — the full
    // hot→warm→cold→warm cycle on one live cache
    let dir = tmpdir("demote");
    let mut m = mk_cache(2, 2, true);
    attach(&mut m, &dir);
    let cfg = m.page_cfg();
    let prompt_a: Vec<i32> = (0..8).collect();
    let prompt_b: Vec<i32> = (100..108).collect();

    let run = |m: &mut CacheManager, seq: u64, prompt: &[i32]| -> (Vec<u32>, Vec<u32>) {
        let reuse = m.start_seq_with_prompt(seq, prompt).unwrap();
        let (k, v) = kv_run(prompt, reuse.tokens, prompt.len(), &cfg);
        m.append_run(seq, &k, &v, prompt.len() - reuse.tokens).unwrap();
        let out = gather_bits(m, seq, prompt.len());
        m.drop_seq(seq);
        out
    };
    let truth_a = run(&mut m, 1, &prompt_a);
    assert_eq!(m.cached_pages(), 2, "A parked warm");
    let _ = run(&mut m, 2, &prompt_b);
    assert_eq!(m.share.pages_evicted, 2, "B's allocs demoted A");
    m.flush_store();
    assert_eq!(m.cold_pages(), 4, "A and B both resolvable cold");

    // A comes back: index miss → store hit → promotion (evicting B)
    let reuse = m.start_seq_with_prompt(3, &prompt_a).unwrap();
    assert_eq!(reuse.tokens, 8, "full prompt served from disk");
    assert_eq!(m.share.pages_promoted, 2);
    assert_eq!(gather_bits(&m, 3, 8), truth_a);
    m.drop_seq(3);
    assert_eq!(m.live_refs(), 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn cold_tail_admission_charges_the_cow_replacement() {
    // a prompt of 3 (tp = 4) persists as a single sealed-tail record.
    // Serving it cold needs TWO pages: one to promote into (owned, so
    // not evictable) and one for the CoW replacement the first decode
    // append forces.  Admission must say no on a 1-page pool — the
    // old math charged one page and the append would have failed
    // mid-serve.
    let dir = tmpdir("coldtail");
    let prompt: Vec<i32> = vec![7, 8, 9];
    let _ = populate(&dir, &prompt, 3);

    {
        let mut m = mk_cache(1, 3, true);
        attach(&mut m, &dir);
        assert_eq!(m.cold_pages(), 1);
        assert!(
            !m.can_admit_prompt(&prompt, 4),
            "1 page cannot host promotion + CoW"
        );
        // the fresh-encode variant has the same shape: an unseen
        // mid-page prompt seals its own tail and CoWs it on the first
        // generated token, so it too needs two pages
        assert!(!m.can_admit_prompt(&[901, 902, 903], 4));
    }
    // with two pages the same request fits and the whole flow runs
    let mut m = mk_cache(2, 3, true);
    attach(&mut m, &dir);
    assert!(m.can_admit_prompt(&prompt, 4));
    let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
    assert_eq!(reuse.tokens, 3, "tail served from disk");
    assert_eq!(m.share.pages_promoted, 1);
    let mut stream = prompt.clone();
    stream.push(99);
    let (k, v) = kv_at(&stream, 3, &m.page_cfg());
    m.append_token(1, &k, &v).unwrap();
    assert_eq!(m.share.cow_copies, 1);
    m.drop_seq(1);
    assert_eq!(m.live_refs(), 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_final_record_degrades_to_partial_reuse() {
    let dir = tmpdir("truncate");
    let prompt = prompt10();
    let truth = populate(&dir, &prompt, 3);
    // chop mid-way through the final record (the sealed tail)
    let seg = single_segment(&dir);
    let len = fs::metadata(&seg).unwrap().len();
    let f = fs::OpenOptions::new().write(true).open(&seg).unwrap();
    f.set_len(len - 7).unwrap();
    drop(f);
    let (reused, out) = warm_boot(&dir, &prompt, 3);
    assert_eq!(reused, 8, "two intact full pages promote; the tail re-encodes");
    assert_eq!(out, truth, "re-encode reproduces identical bytes");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn bit_flipped_payload_fails_crc_and_reencodes() {
    let dir = tmpdir("bitflip");
    let prompt = prompt10();
    let truth = populate(&dir, &prompt, 3);
    // flip one bit inside the first record's payload: the scan stops
    // there, so the whole chain cold-misses
    let seg = single_segment(&dir);
    let mut bytes = fs::read(&seg).unwrap();
    let mid = 60; // inside record 0 (header is 44 bytes)
    bytes[mid] ^= 0x04;
    fs::write(&seg, &bytes).unwrap();
    let (reused, out) = warm_boot(&dir, &prompt, 3);
    assert_eq!(reused, 0, "corrupt root: everything re-encodes");
    assert_eq!(out, truth);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn stale_config_fingerprint_reads_as_miss() {
    let dir = tmpdir("stale");
    let prompt = prompt10();
    let _ = populate(&dir, &prompt, 3);
    // same prompt, different bit width ⇒ different fingerprint: the
    // store's records are invisible, never misdecoded
    let mut m = mk_cache(64, 2, true);
    attach(&mut m, &dir);
    assert_eq!(m.share.pages_rehydrated, 0);
    let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
    assert_eq!(reuse.tokens, 0);
    let cfg = m.page_cfg();
    let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
    m.append_run(1, &k, &v, prompt.len()).unwrap();
    // the 2-bit cache's own pages spill alongside the 3-bit records…
    m.drop_seq(1);
    m.flush_store();
    drop(m);
    // …and each config rehydrates exactly its own.  Opens are
    // sequential: the single-writer lockfile forbids two live stores
    // on one directory, whatever their fingerprints
    let m2 = mk_cache(64, 2, true);
    let m3 = mk_cache(64, 3, true);
    {
        let store2 = PageStore::open(StoreConfig::for_cache(
            dir.clone(),
            m2.fingerprint(),
            m2.page_cfg().page_bytes(),
            0,
        ))
        .unwrap();
        assert_eq!(store2.stats().rehydrated, 3);
        // while store2 lives, a second store on the dir is refused
        assert!(PageStore::open(StoreConfig::for_cache(
            dir.clone(),
            m3.fingerprint(),
            m3.page_cfg().page_bytes(),
            0,
        ))
        .is_err());
    }
    let store3 = PageStore::open(StoreConfig::for_cache(
        dir.clone(),
        m3.fingerprint(),
        m3.page_cfg().page_bytes(),
        0,
    ))
    .unwrap();
    assert_eq!(store3.stats().rehydrated, 3);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn mid_spill_kill_at_any_cut_point_rehydrates_clean() {
    use isoquant::kvcache::store::{record_len, segment_path};
    // simulate a process killed mid-spill: truncate the segment at a
    // spread of byte positions; every resulting store must boot to a
    // clean partial index covering exactly the records the cut left
    // intact, and reproduce byte-identical gathers either way
    let dir = tmpdir("kill");
    let prompt = prompt10();
    let truth = populate(&dir, &prompt, 3);
    let seg = single_segment(&dir);
    let full = fs::read(&seg).unwrap();
    // records in spill order: two 4-token full pages, then the 2-token
    // sealed tail — a cut resurrects exactly the whole records before it
    let page_bytes = mk_cache(1, 3, true).page_cfg().page_bytes();
    let r_full = record_len(4, page_bytes);
    assert_eq!(full.len(), 2 * r_full + record_len(2, page_bytes));
    let expect = |cut: usize| {
        if cut >= 2 * r_full {
            8 // both full pages promote; the tail re-encodes
        } else if cut >= r_full {
            4
        } else {
            0
        }
    };
    let cuts = [1usize, 20, 43, 44, 100, r_full, full.len() / 2, full.len() - 1];
    for &cut in &cuts {
        let case = tmpdir(&format!("kill-cut{cut}"));
        fs::write(segment_path(&case, 0), &full[..cut]).unwrap();
        let (reused, out) = warm_boot(&case, &prompt, 3);
        assert_eq!(reused, expect(cut), "cut {cut}");
        assert_eq!(out, truth, "cut {cut}: bytes must match after partial rehydrate");
        let _ = fs::remove_dir_all(&case);
    }
    let _ = fs::remove_dir_all(&dir);
}
