//! Failure injection: corrupted inputs must produce clean errors (or
//! bounded garbage where the format has no integrity data), never panics
//! or UB.

use isoquant::config::{EngineConfig, RawConfig};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::runtime::Manifest;
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;
use isoquant::util::proplite::check;
use isoquant::util::tensorfile;
use std::path::Path;

#[test]
fn corrupted_manifest_variants_fail_cleanly() {
    let cases = [
        "",                                       // empty
        "{",                                      // truncated
        "[]",                                     // wrong root type
        r#"{"model": {}}"#,                       // missing fields
        r#"{"model": {"vocab": 1}, "artifacts": 3}"#, // wrong types
        r#"{"model": {"vocab": 512, "d_model": 256, "n_heads": 4,
            "d_head": 64, "n_layers": 2, "d_ff": 512, "max_seq": 256,
            "prefill_chunk": 32, "n_params": 1, "serve_batch": 4},
            "artifacts": [{"name": "x"}]}"#,      // artifact missing file
    ];
    for (i, text) in cases.iter().enumerate() {
        let res = Manifest::parse(Path::new("/tmp"), text);
        assert!(res.is_err(), "case {i} should fail: {text:.40}");
    }
}

#[test]
fn corrupted_tensorfile_fails_cleanly() {
    let t = vec![tensorfile::Tensor::from_f32("w", vec![8], &[1.0; 8])];
    let dir = std::env::temp_dir().join("isoquant_failinj");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.bin");
    tensorfile::write_tensorfile(&path, &t).unwrap();
    let good = std::fs::read(&path).unwrap();

    // every single-byte truncation must error, never panic
    for cut in 0..good.len() {
        let res = tensorfile::parse_tensorfile(&good[..cut]);
        assert!(res.is_err(), "truncation at {cut} accepted");
    }
    // random byte flips either parse to the same structure (flip in the
    // payload) or error — never panic
    let mut rng = Rng::new(1);
    for _ in 0..200 {
        let mut bad = good.clone();
        let idx = rng.below(bad.len());
        bad[idx] ^= 1 << rng.below(8);
        let _ = tensorfile::parse_tensorfile(&bad); // must not panic
    }
}

#[test]
fn corrupted_compressed_vector_decodes_to_finite_values() {
    // the packed stage-1 encoding carries no checksum (by design — it is
    // an in-memory cache format); decoding corrupted bytes must still be
    // memory-safe and finite (codes are masked into codebook range)
    let mut rng = Rng::new(2);
    for variant in [Variant::IsoFull, Variant::Rotor3D, Variant::Planar2D] {
        let s = Stage1::new(Stage1Config::new(variant, 64, 3));
        let x = rng.gaussian_vec_f32(64);
        let mut bytes = Vec::new();
        s.encode(&x, &mut bytes);
        for _ in 0..100 {
            let mut bad = bytes.clone();
            // corrupt code bytes only (first 4 bytes are the f32 norm;
            // a flipped norm can legitimately produce inf)
            let idx = 4 + rng.below(bad.len() - 4);
            bad[idx] ^= 0xFF;
            let mut out = vec![0.0f32; 64];
            s.decode(&bad, &mut out);
            assert!(
                out.iter().all(|v| v.is_finite()),
                "{variant:?}: non-finite decode from corrupted codes"
            );
        }
    }
}

#[test]
fn engine_config_rejects_nonsense() {
    for text in [
        "[engine]\nbits = 99",
        "[engine]\nbits = 0",
        "[engine]\nvariant = \"warp-drive\"",
        "[engine]\nquantizer = \"psychic\"",
    ] {
        let raw = RawConfig::parse(text).unwrap();
        assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
    }
}

#[test]
fn server_request_parser_survives_fuzz() {
    use isoquant::server::parse_request;
    let mut rng = Rng::new(3);
    // valid-ish JSON mutations and raw garbage: never panic
    for _ in 0..500 {
        let len = rng.below(60);
        let mut bytes = vec![0u8; len];
        rng.fill_bytes(&mut bytes);
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = parse_request(&s, 1, 16, 256);
    }
    // structured fuzz around the real schema
    check(200, 0xF022, |g| {
        let id = g.usize_in(0, 1 << 20);
        let n = g.usize_in(0, 5);
        let toks: Vec<String> = (0..n).map(|_| g.usize_in(0, 600).to_string()).collect();
        let line = format!(
            r#"{{"id": {id}, "prompt": [{}], "max_new_tokens": {}}}"#,
            toks.join(","),
            g.usize_in(1, 64)
        );
        let req = parse_request(&line, 7, 16, 256).map_err(|e| e.to_string())?;
        if req.prompt.len() != n {
            return Err("token count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn json_parser_survives_mutation_fuzz() {
    let seed_docs = [
        r#"{"a": [1, 2.5, -3e2], "b": {"c": "d\n", "e": null}, "f": true}"#,
        r#"[[[[1]]], {}, "", -0.0]"#,
    ];
    let mut rng = Rng::new(4);
    for doc in seed_docs {
        let bytes = doc.as_bytes();
        for _ in 0..2000 {
            let mut bad = bytes.to_vec();
            for _ in 0..1 + rng.below(3) {
                let idx = rng.below(bad.len());
                bad[idx] = (rng.next_u64() & 0x7F) as u8;
            }
            if let Ok(s) = std::str::from_utf8(&bad) {
                let _ = Json::parse(s); // must not panic
            }
        }
    }
}

#[test]
fn decode_with_wrong_length_is_rejected_in_debug() {
    // encoded_len mismatches are caught by debug_assert in decode; in
    // release we verify the public length accessor instead
    let s = Stage1::new(Stage1Config::new(Variant::IsoFull, 128, 2));
    let x = vec![1.0f32; 128];
    let mut bytes = Vec::new();
    s.encode(&x, &mut bytes);
    assert_eq!(bytes.len(), s.encoded_len());
}

#[test]
fn zero_and_extreme_inputs_are_safe_everywhere() {
    let mut rng = Rng::new(5);
    let patterns: Vec<Vec<f32>> = vec![
        vec![0.0; 128],
        vec![f32::MIN_POSITIVE; 128],
        vec![1e30; 128],
        vec![-1e30; 128],
        (0..128).map(|i| if i == 0 { 1e30 } else { 0.0 }).collect(),
        (0..128).map(|_| rng.gaussian() as f32 * 1e-20).collect(),
    ];
    for variant in [
        Variant::IsoFull,
        Variant::IsoFast,
        Variant::Planar2D,
        Variant::Rotor3D,
        Variant::Grouped8D,
    ] {
        let s = Stage1::new(Stage1Config::new(variant, 128, 2));
        for (i, x) in patterns.iter().enumerate() {
            let mut out = vec![0.0f32; 128];
            s.roundtrip(x, &mut out);
            assert!(
                out.iter().all(|v| v.is_finite()),
                "{variant:?} pattern {i}: non-finite output"
            );
            let mut bytes = Vec::new();
            s.encode(x, &mut bytes);
            s.decode(&bytes, &mut out);
            assert!(out.iter().all(|v| v.is_finite()), "{variant:?} pattern {i} (packed)");
        }
    }
}
