//! Radix prefix-index property tests: any interleaving of
//! {admit-with-shared-prefix, CoW/decode append, drop} on random
//! geometries must keep the radix cache byte-identical to both the
//! flat-index cache and an unshared reference, never use *more* pages
//! than the flat index, return every page ownership to zero, and — with
//! a persistent store attached — survive restarts in either index
//! direction (flat-written stores rehydrate under radix and vice
//! versa, since both serialize the same edge-aware records).
//!
//! The "model" is a deterministic map from a token-id prefix to K/V
//! vectors (same prefix ⇒ same vectors), which is exactly the property
//! that makes prompt prefixes shareable — and what makes a slot-range
//! copy byte-identical to a re-encode.

use isoquant::kvcache::{
    chain_key, CacheManager, GatherWorkspace, PageConfig, PageStore, PrefixIndexKind,
    StoreConfig,
};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::prng::Rng;
use isoquant::util::proplite::{check, Gen};

struct Geometry {
    cfg: PageConfig,
    bits: u8,
}

fn geometry(g: &mut Gen) -> Geometry {
    let dh = 4 * g.usize_in(4, 12); // 16..48, multiple of 4
    let bits = g.usize_in(2, 4) as u8;
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
    Geometry {
        cfg: PageConfig {
            tokens_per_page: g.usize_in(2, 5),
            n_layers: g.usize_in(1, 2),
            n_heads: g.usize_in(1, 2),
            d_head: dh,
            encoded_len: stage1.encoded_len(),
        },
        bits,
    }
}

fn mk_cache(geo: &Geometry, max_pages: usize, sharing: bool, kind: PrefixIndexKind) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, geo.cfg.d_head, geo.bits));
    let mut m = CacheManager::new(stage1, geo.cfg, max_pages);
    m.prefix_sharing = sharing;
    m.index_kind = kind;
    m
}

/// Deterministic K/V for the token at position `t` of `stream`: seeded
/// by the chained hash of `stream[..=t]`, so equal prefixes produce
/// equal vectors.
fn kv_at(stream: &[i32], t: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let seed = chain_key(None, &stream[..=t], 0xBEEF).0;
    let mut rng = Rng::new(seed);
    let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
    (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
}

/// Flatten tokens `from..to` of `stream` into one token-major run.
fn kv_run(stream: &[i32], from: usize, to: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::new();
    let mut v = Vec::new();
    for t in from..to {
        let (tk, tv) = kv_at(stream, t, cfg);
        k.extend_from_slice(&tk);
        v.extend_from_slice(&tv);
    }
    (k, v)
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Gather `seq` from all three caches through both the batched path and
/// the per-vector oracle, demanding bit-identical results everywhere.
fn verify_seq(
    radix: &CacheManager,
    flat: &CacheManager,
    unshared: &CacheManager,
    seq: u64,
    len: usize,
    cfg: &PageConfig,
    ws: &mut GatherWorkspace,
) -> Result<(), String> {
    let t_max = len.max(1) + 2;
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut kr, mut vr) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    let (mut ko, mut vo) = (vec![1.0f32; sz], vec![1.0f32; sz]);
    let (mut kf, mut vf) = (vec![2.0f32; sz], vec![2.0f32; sz]);
    let (mut ku, mut vu) = (vec![3.0f32; sz], vec![3.0f32; sz]);
    let n1 = radix
        .gather_ws(seq, t_max, &mut kr, &mut vr, ws)
        .map_err(|e| e.to_string())?;
    let n2 = radix
        .gather_reference(seq, t_max, &mut ko, &mut vo)
        .map_err(|e| e.to_string())?;
    let n3 = flat
        .gather_reference(seq, t_max, &mut kf, &mut vf)
        .map_err(|e| e.to_string())?;
    let n4 = unshared
        .gather_reference(seq, t_max, &mut ku, &mut vu)
        .map_err(|e| e.to_string())?;
    if n1 != len || n2 != len || n3 != len || n4 != len {
        return Err(format!("seq {seq}: lengths {n1}/{n2}/{n3}/{n4} != {len}"));
    }
    if bits_of(&kr) != bits_of(&ko) || bits_of(&vr) != bits_of(&vo) {
        return Err(format!("seq {seq}: radix batched gather != reference"));
    }
    if bits_of(&kr) != bits_of(&ku) || bits_of(&vr) != bits_of(&vu) {
        return Err(format!("seq {seq}: radix cache != unshared cache"));
    }
    if bits_of(&kf) != bits_of(&ku) || bits_of(&vf) != bits_of(&vu) {
        return Err(format!("seq {seq}: flat cache != unshared cache"));
    }
    Ok(())
}

/// The core property: random prompt mixes with shared stems, mid-prompt
/// divergence, decode appends, and drops under pool pressure — the
/// radix cache must stay byte-identical to the flat and unshared
/// caches, never exceed the flat cache's page count, and leak nothing.
#[test]
fn prop_radix_bit_identical_to_flat_and_unshared_never_more_pages() {
    check(20, 0x4AD1, |g| {
        let geo = geometry(g);
        let cfg = geo.cfg;
        // identical constrained pools for both shared caches; the
        // unshared reference never shares and never evicts
        let pool = g.usize_in(24, 96);
        let mut radix = mk_cache(&geo, pool, true, PrefixIndexKind::Radix);
        let mut flat = mk_cache(&geo, pool, true, PrefixIndexKind::Flat);
        let mut unshared = mk_cache(&geo, 4096, false, PrefixIndexKind::Flat);
        let mut ws = GatherWorkspace::new();

        // base prompts the ops draw shared prefixes from
        let bases: Vec<Vec<i32>> = (0..3)
            .map(|b| {
                let n = g.usize_in(2 * cfg.tokens_per_page, 6 * cfg.tokens_per_page);
                (0..n).map(|i| (b * 1000 + i) as i32).collect()
            })
            .collect();

        // live sequences: (seq, full token stream so far)
        let mut live: Vec<(u64, Vec<i32>)> = Vec::new();
        let mut next_seq = 0u64;
        let mut next_tok = 50_000i32;

        for _ in 0..30 {
            match g.usize_in(0, 3) {
                // admit a prompt that is a (sometimes twisted) prefix
                // of a base prompt — mid-prompt and last-token twists
                // exercise sub-page divergence on the radix side
                0 => {
                    let base = g.choose(&bases).clone();
                    let plen = g.usize_in(1, base.len());
                    let mut prompt = base[..plen].to_vec();
                    if g.bool() && g.bool() {
                        let i = g.usize_in(0, plen - 1);
                        prompt[i] = next_tok;
                        next_tok += 1;
                    }
                    // admit only when *both* shared caches accept, so
                    // the page-count comparison tracks identical loads
                    if !radix.can_admit_prompt(&prompt, prompt.len())
                        || !flat.can_admit_prompt(&prompt, prompt.len())
                    {
                        continue;
                    }
                    next_seq += 1;
                    for m in [&mut radix, &mut flat] {
                        let reuse = m
                            .start_seq_with_prompt(next_seq, &prompt)
                            .map_err(|e| e.to_string())?;
                        if reuse.tokens > prompt.len() {
                            return Err(format!(
                                "reuse {} > prompt {}",
                                reuse.tokens,
                                prompt.len()
                            ));
                        }
                        let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
                        m.append_run(next_seq, &k, &v, prompt.len() - reuse.tokens)
                            .map_err(|e| format!("admitted but append failed: {e}"))?;
                    }
                    unshared.start_seq(next_seq).map_err(|e| e.to_string())?;
                    let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
                    unshared
                        .append_run(next_seq, &k, &v, prompt.len())
                        .map_err(|e| e.to_string())?;
                    live.push((next_seq, prompt));
                }
                // decode append (CoW when the tail is a shared sealed
                // page; in-place when it is an open radix copy)
                1 if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, stream) = &mut live[i];
                    stream.push(next_tok);
                    next_tok += 1;
                    let t = stream.len() - 1;
                    let (k, v) = kv_at(stream, t, &cfg);
                    match flat.append_token(*seq, &k, &v) {
                        Ok(()) => {
                            // the radix cache never holds more pages
                            // than flat, so the same append must fit
                            radix.append_token(*seq, &k, &v).map_err(|e| {
                                format!("radix append failed where flat succeeded: {e}")
                            })?;
                            unshared
                                .append_token(*seq, &k, &v)
                                .map_err(|e| e.to_string())?;
                        }
                        Err(_) => {
                            stream.pop(); // pool exhausted: keep streams aligned
                        }
                    }
                }
                // drop
                2 if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, _) = live.swap_remove(i);
                    radix.drop_seq(seq);
                    flat.drop_seq(seq);
                    unshared.drop_seq(seq);
                }
                // verify a random live sequence through every path
                _ if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, stream) = &live[i];
                    verify_seq(&radix, &flat, &unshared, *seq, stream.len(), &cfg, &mut ws)?;
                }
                _ => {}
            }
            // the sub-page index must never cost pages: identical op
            // sequence, identical pool — radix stays at or below flat
            if radix.pages_in_use() > flat.pages_in_use() {
                return Err(format!(
                    "radix uses {} pages where flat uses {}",
                    radix.pages_in_use(),
                    flat.pages_in_use()
                ));
            }
        }

        // final sweep: every live sequence still byte-identical
        for (seq, stream) in &live {
            verify_seq(&radix, &flat, &unshared, *seq, stream.len(), &cfg, &mut ws)?;
        }

        // teardown: all ownerships return to zero on both shared caches
        for (seq, _) in live.drain(..) {
            radix.drop_seq(seq);
            flat.drop_seq(seq);
            unshared.drop_seq(seq);
        }
        for (name, m) in [("radix", &radix), ("flat", &flat)] {
            if m.live_refs() != 0 {
                return Err(format!("{name}: {} refs leaked", m.live_refs()));
            }
            if m.live_pages() != 0 {
                return Err(format!("{name}: {} live pages leaked", m.live_pages()));
            }
        }
        if unshared.pages_in_use() != 0 {
            return Err("unshared cache leaked pages".into());
        }
        Ok(())
    });
}

/// High fan-out acceptance scenario: many clients share a long stem and
/// diverge only in the last token of the prompt.  The radix index must
/// admit at least as many lanes as flat under the same constrained
/// pool, allocate strictly fewer pages, and re-encode only the
/// divergent suffix (slot copies do the rest).
#[test]
fn high_fanout_divergent_tails_radix_beats_flat() {
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 3)).encoded_len(),
        },
        bits: 3,
    };
    let cfg = geo.cfg;
    let clients = 12u64;
    let stem: Vec<i32> = (0..10).collect(); // 2.5 pages: mid-page stem end
    let run = |m: &mut CacheManager, un: &mut CacheManager| -> (usize, Vec<u64>) {
        let mut admitted = Vec::new();
        for c in 0..clients {
            let seq = c + 1;
            let mut prompt = stem.clone();
            prompt.push(7000 + c as i32); // 1-token divergent tail
            // generous budget: prompt + 2 decode tokens
            if !m.can_admit_prompt(&prompt, prompt.len() + 2) {
                continue;
            }
            let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
            let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
            m.append_run(seq, &k, &v, prompt.len() - reuse.tokens).unwrap();
            un.start_seq(seq).unwrap();
            let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
            un.append_run(seq, &k, &v, prompt.len()).unwrap();
            // two decode tokens: triggers the tail CoW wherever the
            // tail sealed, and stays in place on an open radix copy
            let mut stream = prompt.clone();
            for d in 0..2 {
                stream.push(90_000 + (c as i32) * 10 + d);
                let (tk, tv) = kv_at(&stream, stream.len() - 1, &cfg);
                m.append_token(seq, &tk, &tv).unwrap();
                un.append_token(seq, &tk, &tv).unwrap();
            }
            admitted.push(seq);
        }
        (m.pages_in_use(), admitted)
    };

    // ample pool first: page economics with everyone admitted
    let mut radix = mk_cache(&geo, 4096, true, PrefixIndexKind::Radix);
    let mut flat = mk_cache(&geo, 4096, true, PrefixIndexKind::Flat);
    let mut un_r = mk_cache(&geo, 4096, false, PrefixIndexKind::Flat);
    let mut un_f = mk_cache(&geo, 4096, false, PrefixIndexKind::Flat);
    let (radix_pages, radix_adm) = run(&mut radix, &mut un_r);
    let (flat_pages, flat_adm) = run(&mut flat, &mut un_f);
    assert_eq!(radix_adm.len(), clients as usize);
    assert_eq!(flat_adm.len(), clients as usize);
    assert!(
        radix_pages < flat_pages,
        "radix must allocate strictly fewer pages at high fan-out: {radix_pages} vs {flat_pages}"
    );
    // followers copied the 2 shared tail slots instead of re-encoding
    assert_eq!(radix.share.slots_copied, 2 * (clients - 1));
    assert_eq!(radix.share.tail_copies, clients - 1);
    // only the cold client's sealed tail ever CoWs under radix
    assert_eq!(radix.share.cow_copies, 1);
    assert_eq!(flat.share.cow_copies, clients);
    // every gather byte-identical to the unshared reference
    let mut ws = GatherWorkspace::new();
    for &seq in &radix_adm {
        let len = stem.len() + 1 + 2;
        verify_seq(&radix, &flat, &un_r, seq, len, &cfg, &mut ws).unwrap();
    }
    for &seq in &radix_adm {
        radix.drop_seq(seq);
        flat.drop_seq(seq);
    }
    assert_eq!(radix.live_refs(), 0);
    assert_eq!(flat.live_refs(), 0);

    // constrained pool: the pages radix saves become admitted lanes
    let mut radix = mk_cache(&geo, 24, true, PrefixIndexKind::Radix);
    let mut flat = mk_cache(&geo, 24, true, PrefixIndexKind::Flat);
    let mut un_r = mk_cache(&geo, 4096, false, PrefixIndexKind::Flat);
    let mut un_f = mk_cache(&geo, 4096, false, PrefixIndexKind::Flat);
    let (_, radix_adm) = run(&mut radix, &mut un_r);
    let (_, flat_adm) = run(&mut flat, &mut un_f);
    assert!(
        radix_adm.len() >= flat_adm.len(),
        "radix admitted {} < flat {}",
        radix_adm.len(),
        flat_adm.len()
    );
    for &seq in &radix_adm {
        let len = stem.len() + 1 + 2;
        let t_max = len + 2;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut kr, mut vr) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let (mut ku, mut vu) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        radix.gather(seq, t_max, &mut kr, &mut vr).unwrap();
        un_r.gather(seq, t_max, &mut ku, &mut vu).unwrap();
        assert_eq!(bits_of(&kr), bits_of(&ku), "seq {seq} under pressure");
        assert_eq!(bits_of(&vr), bits_of(&vu), "seq {seq} under pressure");
    }
}

/// Admission parity with flat on an adopted sealed tail: the counted
/// tail slot is what pays for the decode-time CoW, so a same-prompt
/// follower needs exactly ONE page under either index backend — the
/// radix math must not double-charge the adopted tail with a CoW
/// surcharge (which would deny admissions flat accepts at exact pool
/// boundaries).
#[test]
fn adopted_tail_admission_matches_flat_at_pool_boundary() {
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 3)).encoded_len(),
        },
        bits: 3,
    };
    let cfg = geo.cfg;
    let prompt: Vec<i32> = (0..9).collect(); // 2 full pages + 1-token tail
    for kind in [PrefixIndexKind::Flat, PrefixIndexKind::Radix] {
        // pool of 4: the first client's 3 pages leave exactly 1 free
        let mut m = mk_cache(&geo, 4, true, kind);
        m.start_seq_with_prompt(1, &prompt).unwrap();
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        m.append_run(1, &k, &v, prompt.len()).unwrap();
        assert_eq!(m.pages_in_use(), 3);
        // total 11 = prompt 9 + 2 decode: a follower adopts all three
        // pages and needs only the CoW replacement the tail slot counts
        assert!(
            m.can_admit_prompt(&prompt, 11),
            "{kind:?}: follower must fit in the single remaining page"
        );
        let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
        assert_eq!(reuse.tokens, prompt.len(), "{kind:?}");
        assert_eq!(reuse.pages, 3, "{kind:?}");
        // and the decode really completes inside that page budget
        let mut stream = prompt.clone();
        for d in 0..2 {
            stream.push(40_000 + d);
            let (tk, tv) = kv_at(&stream, stream.len() - 1, &cfg);
            m.append_token(2, &tk, &tv).unwrap();
        }
        assert_eq!(m.pages_in_use(), 4, "{kind:?}: one CoW page, nothing more");
        assert_eq!(m.share.cow_copies, 1, "{kind:?}");
        m.drop_seq(1);
        m.drop_seq(2);
        assert_eq!(m.live_refs(), 0, "{kind:?}");
    }
}

/// A page whose span is fully resident but split across two source
/// pages (the shared head on the first publisher's page, the divergent
/// suffix on a follower's) must be *assembled* by slot copies and must
/// not truncate the plan: positions after it stay adoptable.
#[test]
fn fully_covered_multi_source_page_assembles_and_keeps_adopting() {
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 4)).encoded_len(),
        },
        bits: 4,
    };
    let cfg = geo.cfg;
    let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
    let mut un = mk_cache(&geo, 64, false, PrefixIndexKind::Flat);
    // A: 12 tokens (3 full pages); B: diverges at token 5 (mid-page 1)
    let prompt_a: Vec<i32> = (0..12).collect();
    let mut prompt_b = prompt_a.clone();
    prompt_b[5] = 777;
    for (seq, prompt) in [(1u64, &prompt_a), (2, &prompt_b)] {
        let reuse = m.start_seq_with_prompt(seq, prompt).unwrap();
        let (k, v) = kv_run(prompt, reuse.tokens, prompt.len(), &cfg);
        m.append_run(seq, &k, &v, prompt.len() - reuse.tokens).unwrap();
    }
    // B published its divergent suffix of page 1 (split of A's node)
    // and its own page 2; C = B's exact prompt: page 0 adopts, page 1
    // assembles from A's slot 0 + B's slots 1..4, page 2 ADOPTS B's —
    // the whole prompt is served without re-encoding a single token
    let before = m.pages_in_use();
    let reuse = m.start_seq_with_prompt(3, &prompt_b).unwrap();
    assert_eq!(reuse.tokens, 12, "assembly must not truncate the walk");
    assert_eq!(reuse.pages, 2, "pages 0 and 2 adopt whole");
    assert_eq!(m.pages_in_use(), before + 1, "only the assembled page allocates");
    assert_eq!(m.share.slots_copied, 1 + 4, "B copied 1 slot, C copied a full span");
    // byte-identity vs a fresh unshared encode of B's prompt
    un.start_seq(3).unwrap();
    let (k, v) = kv_run(&prompt_b, 0, prompt_b.len(), &cfg);
    un.append_run(3, &k, &v, prompt_b.len()).unwrap();
    let t_max = 12;
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut km, mut vm) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    let (mut ku, mut vu) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    m.gather(3, t_max, &mut km, &mut vm).unwrap();
    un.gather(3, t_max, &mut ku, &mut vu).unwrap();
    assert_eq!(bits_of(&km), bits_of(&ku));
    assert_eq!(bits_of(&vm), bits_of(&vu));
    for seq in 1..=3 {
        m.drop_seq(seq);
    }
    assert_eq!(m.live_refs(), 0);
    assert_eq!(m.live_pages(), 0);
}

/// Persist → restart in both index directions: a store written by a
/// flat boot rehydrates fully under a radix boot and vice versa —
/// the radix spill derives the same edge-aware record keys (parent
/// chain + covered run) the flat index uses.
#[test]
fn radix_store_roundtrip_and_cross_index_compat() {
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 4)).encoded_len(),
        },
        bits: 4,
    };
    let cfg = geo.cfg;
    let prompt: Vec<i32> = (0..10).map(|i| 300 + i).collect(); // 2 full + tail of 2
    let attach = |m: &mut CacheManager, dir: &std::path::Path| {
        let store = PageStore::open(StoreConfig::for_cache(
            dir.to_path_buf(),
            m.fingerprint(),
            m.page_cfg().page_bytes(),
            0,
        ))
        .unwrap();
        m.attach_store(store);
    };
    let populate = |kind: PrefixIndexKind, dir: &std::path::Path| {
        let mut m = mk_cache(&geo, 64, true, kind);
        attach(&mut m, dir);
        m.start_seq_with_prompt(1, &prompt).unwrap();
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        m.append_run(1, &k, &v, prompt.len()).unwrap();
        m.drop_seq(1); // parks + spills all three prompt pages
        m.flush_store();
        assert_eq!(m.share.pages_spilled, 3, "{kind:?} boot must spill the chain");
    };
    let warm_boot = |kind: PrefixIndexKind, dir: &std::path::Path| {
        let mut m = mk_cache(&geo, 64, true, kind);
        attach(&mut m, dir);
        assert!(m.can_admit_prompt(&prompt, prompt.len()));
        let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
        assert_eq!(
            reuse.tokens,
            prompt.len(),
            "{kind:?} warm boot must cover the whole prompt from disk"
        );
        assert_eq!(m.share.pages_promoted, 3);
        // byte-identical to a never-persisted unshared cache
        let mut un = mk_cache(&geo, 64, false, PrefixIndexKind::Flat);
        un.start_seq(1).unwrap();
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        un.append_run(1, &k, &v, prompt.len()).unwrap();
        let t_max = prompt.len();
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut km, mut vm) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let (mut ku, mut vu) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        m.gather(1, t_max, &mut km, &mut vm).unwrap();
        un.gather(1, t_max, &mut ku, &mut vu).unwrap();
        assert_eq!(bits_of(&km), bits_of(&ku), "{kind:?} K after promotion");
        assert_eq!(bits_of(&vm), bits_of(&vu), "{kind:?} V after promotion");
        m.drop_seq(1);
        assert_eq!(m.live_refs(), 0);
    };
    for (writer, reader) in [
        (PrefixIndexKind::Flat, PrefixIndexKind::Radix),
        (PrefixIndexKind::Radix, PrefixIndexKind::Flat),
        (PrefixIndexKind::Radix, PrefixIndexKind::Radix),
    ] {
        let dir = std::env::temp_dir().join(format!(
            "isoquant-radix-store-{}-{}-{}",
            std::process::id(),
            writer.name(),
            reader.name(),
        ));
        let _ = std::fs::remove_dir_all(&dir);
        populate(writer, &dir);
        warm_boot(reader, &dir);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
