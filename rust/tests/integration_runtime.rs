//! Integration tests over the PJRT runtime, the serving engine, and the
//! TCP server.  These need `make artifacts`; they SKIP (pass trivially,
//! with a note) when artifacts are absent so `cargo test` works in a
//! fresh checkout, and exercise the real three-layer stack when present.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, FinishReason, Request};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::runtime::{HostTensor, Runtime, ServingModel};
use isoquant::util::prng::Rng;

/// The XLA CPU runtime does not tolerate concurrent PJRT client
/// creation in one process; serialize every test that touches PJRT.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = isoquant::runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts not built; skipping runtime integration test");
        None
    }
}

#[test]
fn stage1_parity_native_vs_hlo() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let specs: Vec<_> = rt
        .manifest
        .stage1_artifacts()
        .into_iter()
        .cloned()
        .collect();
    assert!(!specs.is_empty());
    for spec in specs {
        let variant = Variant::from_name(spec.meta.get("variant").unwrap()).unwrap();
        let d = spec.meta_usize("d").unwrap();
        let bits = spec.meta_usize("bits").unwrap() as u8;
        let batch = spec.meta_usize("batch").unwrap();
        let stage = Stage1::new(Stage1Config::new(variant, d, bits));
        let mut rng = Rng::new(0x7e57 + d as u64 * 31 + bits as u64);
        let x = rng.gaussian_vec_f32(batch * d);
        let mut native = vec![0.0f32; batch * d];
        stage.roundtrip_batch(&x, &mut native, batch);
        let mut inputs = vec![HostTensor::F32(x, vec![batch, d])];
        for t in stage.bank.to_hlo_inputs() {
            inputs.push(HostTensor::F32(t.as_f32().unwrap(), t.shape.clone()));
        }
        let outs = rt.run_f32(&spec.name, &inputs).unwrap();
        let worst = native
            .iter()
            .zip(&outs[0])
            .map(|(&a, &b)| ((a - b) as f64).abs())
            .fold(0.0f64, f64::max);
        assert!(worst < 5e-5, "{}: native-vs-HLO max|Δ| = {worst}", spec.name);
    }
}

#[test]
fn decode_step_shapes_and_determinism() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut model = ServingModel::load(&dir).unwrap();
    let m = model.meta.clone();
    let numel = model.cache_numel();
    let k = vec![0.0f32; numel];
    let v = vec![0.0f32; numel];
    let toks = vec![1i32; m.serve_batch];
    let pos = vec![0i32; m.serve_batch];
    let out1 = model.decode_step(&toks, &pos, &k, &v).unwrap();
    assert_eq!(out1.logits.len(), m.serve_batch * m.vocab);
    assert_eq!(
        out1.k_new.len(),
        m.n_layers * m.serve_batch * m.n_heads * m.d_head
    );
    assert!(out1.logits.iter().all(|x| x.is_finite()));
    let out2 = model.decode_step(&toks, &pos, &k, &v).unwrap();
    assert_eq!(out1.logits, out2.logits, "XLA decode must be deterministic");
}

#[test]
fn prefill_then_decode_consistent_with_pure_decode() {
    // feeding a prompt via prefill_chunk and then decoding must produce
    // the same next-token logits as feeding the prompt token-by-token
    // through decode_step with exact caches.
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut model = ServingModel::load(&dir).unwrap();
    let m = model.meta.clone();
    let b = m.serve_batch;
    let numel = model.cache_numel();
    let mut rng = Rng::new(99);
    let plen = 5usize;
    let prompt: Vec<i32> = (0..plen).map(|_| rng.below(m.vocab) as i32).collect();

    // path A: prefill chunk (prompt in lane 0, zero-padded)
    let mut toks_a = vec![0i32; b * m.prefill_chunk];
    toks_a[..plen].copy_from_slice(&prompt);
    let zeros_k = vec![0.0f32; numel];
    let zeros_v = vec![0.0f32; numel];
    let pos0 = vec![0i32; b];
    let out_a = model
        .prefill_chunk(&toks_a, &pos0, &zeros_k, &zeros_v)
        .unwrap();
    let logits_a =
        &out_a.logits[(0 * m.prefill_chunk + (plen - 1)) * m.vocab..][..m.vocab];

    // path B: token-by-token decode with exact cache writes
    let mut k_cache = vec![0.0f32; numel];
    let mut v_cache = vec![0.0f32; numel];
    let mut logits_b = Vec::new();
    for (step, &t) in prompt.iter().enumerate() {
        let mut toks = vec![0i32; b];
        toks[0] = t;
        let mut pos = vec![0i32; b];
        pos[0] = step as i32;
        let out = model.decode_step(&toks, &pos, &k_cache, &v_cache).unwrap();
        let (l, h, dh, tmax) = (m.n_layers, m.n_heads, m.d_head, m.max_seq);
        for layer in 0..l {
            for head in 0..h {
                let src = (((layer * b) + 0) * h + head) * dh;
                let dst = ((((layer * b) + 0) * h + head) * tmax + step) * dh;
                k_cache[dst..dst + dh].copy_from_slice(&out.k_new[src..src + dh]);
                v_cache[dst..dst + dh].copy_from_slice(&out.v_new[src..src + dh]);
            }
        }
        logits_b = out.logits[..m.vocab].to_vec();
    }
    let worst = logits_a
        .iter()
        .zip(&logits_b)
        .map(|(&a, &b)| ((a - b) as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(worst < 1e-3, "prefill vs decode logits diverge: {worst}");
}

#[test]
fn engine_serves_requests_end_to_end() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let model = ServingModel::load(&dir).unwrap();
    let vocab = model.meta.vocab;
    let cfg = EngineConfig::default();
    let mut engine = Engine::new(model, cfg).unwrap();
    let mut rng = Rng::new(5);
    let n_req = 6;
    for i in 0..n_req {
        let plen = 3 + rng.below(40);
        engine.submit(Request::new(
            i,
            (0..plen).map(|_| rng.below(vocab) as i32).collect(),
            8,
        ));
    }
    let completions = engine.run_to_completion().unwrap();
    assert_eq!(completions.len(), n_req as usize);
    for c in &completions {
        assert_eq!(c.finish, FinishReason::MaxTokens, "req {}", c.id);
        assert_eq!(c.tokens.len(), 8, "req {}", c.id);
        assert!(c.tokens.iter().all(|&t| (t as usize) < vocab));
        assert!(c.timing.ttft_us().unwrap() > 0.0);
    }
    // all pages must be released once everything finished
    assert_eq!(engine.cache.pages_in_use(), 0);
    assert_eq!(engine.active(), 0);
}

#[test]
fn engine_rejects_oversized_and_continues() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let model = ServingModel::load(&dir).unwrap();
    let max_seq = model.meta.max_seq;
    let vocab = model.meta.vocab;
    let mut engine = Engine::new(model, EngineConfig::default()).unwrap();
    engine.submit(Request::new(1, vec![1; max_seq + 10], 4));
    engine.submit(Request::new(2, vec![2; 4], 4));
    let completions = engine.run_to_completion().unwrap();
    assert_eq!(completions.len(), 2);
    let rejected = completions.iter().find(|c| c.id == 1).unwrap();
    assert_eq!(rejected.finish, FinishReason::Rejected);
    let ok = completions.iter().find(|c| c.id == 2).unwrap();
    assert_eq!(ok.finish, FinishReason::MaxTokens);
    assert_eq!(ok.tokens.len(), 4);
    let _ = vocab;
}

#[test]
fn engine_deterministic_across_runs() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let run = || {
        let model = ServingModel::load(&dir).unwrap();
        let mut engine = Engine::new(model, EngineConfig::default()).unwrap();
        engine.submit(Request::new(0, vec![3, 1, 4, 1, 5], 6));
        engine.run_to_completion().unwrap()[0].tokens.clone()
    };
    assert_eq!(run(), run(), "greedy decode must be reproducible");
}

#[test]
fn compressed_decode_tracks_exact_decode() {
    // generation under 4-bit IsoQuant-Full compression should mostly
    // agree with exact-cache generation over a short horizon
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let model = ServingModel::load(&dir).unwrap();
    let vocab = model.meta.vocab;
    let mut cfg = EngineConfig::default();
    cfg.variant = Variant::IsoFull;
    cfg.bits = 4;
    let mut engine = Engine::new(model, cfg).unwrap();
    let prompt: Vec<i32> = (0..12).map(|i| ((i * 37) % vocab) as i32).collect();
    engine.submit(Request::new(0, prompt.clone(), 8));
    let comp = engine.run_to_completion().unwrap();
    let compressed_tokens = &comp[0].tokens;

    // exact reference via direct decode-step driving
    let mut model = engine.model;
    let m = model.meta.clone();
    let b = m.serve_batch;
    let numel = m.n_layers * b * m.n_heads * m.max_seq * m.d_head;
    let mut k_cache = vec![0.0f32; numel];
    let mut v_cache = vec![0.0f32; numel];
    let mut generated = Vec::new();
    let mut last = prompt[0];
    for step in 0..(prompt.len() + 8 - 1) {
        let mut toks = vec![0i32; b];
        toks[0] = last;
        let mut pos = vec![0i32; b];
        pos[0] = step as i32;
        let out = model.decode_step(&toks, &pos, &k_cache, &v_cache).unwrap();
        let (l, h, dh, tmax) = (m.n_layers, m.n_heads, m.d_head, m.max_seq);
        for layer in 0..l {
            for head in 0..h {
                let src = (((layer * b) + 0) * h + head) * dh;
                let dst = ((((layer * b) + 0) * h + head) * tmax + step) * dh;
                k_cache[dst..dst + dh].copy_from_slice(&out.k_new[src..src + dh]);
                v_cache[dst..dst + dh].copy_from_slice(&out.v_new[src..src + dh]);
            }
        }
        if step + 1 < prompt.len() {
            last = prompt[step + 1];
        } else {
            last = isoquant::metrics::argmax(&out.logits[..m.vocab]) as i32;
            generated.push(last);
        }
    }
    let agree = generated
        .iter()
        .zip(compressed_tokens)
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree * 2 >= generated.len(),
        "compressed generation diverged too much: {agree}/{} (exact {generated:?} vs compressed {compressed_tokens:?})",
        generated.len()
    );
}

#[test]
fn tcp_server_roundtrip() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let model = ServingModel::load(&dir).unwrap();
    let mut cfg = EngineConfig::default();
    cfg.bind = "127.0.0.1:47391".to_string();
    let engine = Engine::new(model, cfg.clone()).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let bind = cfg.bind.clone();
    // engine is !Send → run the server on a dedicated *scoped* thread is
    // impossible; instead run it on a plain thread created BEFORE the
    // engine... we cannot move the engine.  Run the server on the main
    // test thread and the client on a helper thread instead.
    let client = std::thread::spawn(move || {
        // wait for the listener
        let mut ok = None;
        for _ in 0..100 {
            std::thread::sleep(std::time::Duration::from_millis(30));
            if let Ok(c) = isoquant::server::Client::connect(&bind) {
                ok = Some(c);
                break;
            }
        }
        let mut client = ok.expect("server did not come up");
        let resp = client.generate(42, &[5, 6, 7], 4).expect("generate");
        stop2.store(true, Ordering::SeqCst);
        resp
    });
    isoquant::server::serve(engine, &cfg.bind, stop).unwrap();
    let resp = client.join().unwrap();
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(42.0));
    assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(resp.get("finish").unwrap().as_str(), Some("max_tokens"));
}
