//! SIMD ↔ scalar bit-exactness: every kernel backend the host supports
//! must produce byte-identical encodes and bit-identical decodes to the
//! scalar reference (`KernelBackend::Scalar`), across the full Table-2
//! sweep, ragged tails, strided page layouts, and the f16 roundtrip
//! path.  This is the contract that makes the `kernel_backend` knob
//! safe: cache pages written under one backend decode identically under
//! any other, so the backend can never change served results.

use isoquant::quant::kernels::{KernelBackend, Resolved};
use isoquant::quant::{
    mse, BatchScratch, PackedSink, ParamBank, QuantKind, Stage1, Stage1Config, Variant,
};
use isoquant::util::f16;
use isoquant::util::prng::Rng;
use isoquant::util::proplite::check;

/// The variants with SIMD kernels (the rest always run scalar and are
/// covered by the existing proptest suite).
const SIMD_VARIANTS: [Variant; 3] = [Variant::IsoFull, Variant::IsoFast, Variant::Planar2D];

/// Every backend worth testing on this host: the scalar reference plus
/// whatever `Auto` resolves to (AVX2 on x86_64 with the feature, NEON
/// on aarch64).  Explicit backend requests that the host cannot run
/// resolve to scalar, so testing them adds nothing.
fn host_backends() -> Vec<KernelBackend> {
    let mut v = vec![KernelBackend::Scalar];
    if KernelBackend::Auto.resolve() != Resolved::Scalar {
        v.push(KernelBackend::Auto);
    }
    // Auto stops at AVX2, so the wider backend needs its own entry when
    // the host can actually run it.
    if KernelBackend::Avx512.resolve() == Resolved::Avx512 {
        v.push(KernelBackend::Avx512);
    }
    v
}

fn stage(variant: Variant, d: usize, bits: u8, backend: KernelBackend, bank: &ParamBank) -> Stage1 {
    Stage1::with_bank(
        Stage1Config::new(variant, d, bits).with_backend(backend),
        bank.clone(),
    )
}

/// Assert `simd` and the scalar `reference` agree bit-for-bit on
/// per-vector encode/decode and on the batch paths (contiguous and
/// strided with garbage gaps) for one input batch.
fn assert_backend_bitexact(
    reference: &Stage1,
    simd: &Stage1,
    x: &[f32],
    n: usize,
    gap: usize,
) -> Result<(), String> {
    let d = reference.d();
    let enc = reference.encoded_len();
    // per-vector encode: byte-identical records
    let mut enc_ref = Vec::new();
    let mut enc_simd = Vec::new();
    for i in 0..n {
        reference.encode(&x[i * d..(i + 1) * d], &mut enc_ref);
        simd.encode(&x[i * d..(i + 1) * d], &mut enc_simd);
    }
    if enc_ref != enc_simd {
        return Err("per-vector encode bytes differ".into());
    }
    // per-vector decode: bit-identical reconstructions
    let mut dec_ref = vec![0.0f32; d];
    let mut dec_simd = vec![0.0f32; d];
    for i in 0..n {
        reference.decode(&enc_ref[i * enc..(i + 1) * enc], &mut dec_ref);
        simd.decode(&enc_ref[i * enc..(i + 1) * enc], &mut dec_simd);
        for j in 0..d {
            if dec_ref[j].to_bits() != dec_simd[j].to_bits() {
                return Err(format!(
                    "per-vector decode not bit-exact at vec {i} coord {j}: {} vs {}",
                    dec_ref[j], dec_simd[j]
                ));
            }
        }
    }
    // batch encode (tile path): byte-identical to the scalar batch
    let mut sink_ref = PackedSink::new();
    let mut sink_simd = PackedSink::new();
    reference.encode_batch(x, n, &mut sink_ref);
    simd.encode_batch(x, n, &mut sink_simd);
    if sink_ref.as_bytes() != sink_simd.as_bytes() {
        return Err("encode_batch bytes differ".into());
    }
    // strided batch decode (tile path) over a ragged page image
    if n > 0 {
        let stride = enc + gap;
        let mut page = vec![0xEEu8; n * stride];
        for i in 0..n {
            page[i * stride..i * stride + enc].copy_from_slice(sink_ref.encoded(i));
        }
        let mut scratch = BatchScratch::new();
        let mut got_ref = vec![0.0f32; n * d];
        let mut got_simd = vec![0.0f32; n * d];
        reference.decode_batch_strided(&page, stride, n, &mut got_ref, &mut scratch);
        simd.decode_batch_strided(&page, stride, n, &mut got_simd, &mut scratch);
        for j in 0..n * d {
            if got_ref[j].to_bits() != got_simd[j].to_bits() {
                return Err(format!("strided batch decode not bit-exact at {j}"));
            }
        }
    }
    Ok(())
}

#[test]
fn kernel_bitexact_full_table2_sweep() {
    // acceptance sweep: every SIMD variant × d ∈ {128, 256, 512} × bits
    // ∈ {2, 3, 4} × every host backend, n past the tile width so both
    // tile and remainder paths run
    let mut rng = Rng::new(0x51D);
    for backend in host_backends() {
        for variant in SIMD_VARIANTS {
            for d in [128usize, 256, 512] {
                let bank = ParamBank::random(variant, d, 0x5EED ^ d as u64);
                for bits in [2u8, 3, 4] {
                    let reference = stage(variant, d, bits, KernelBackend::Scalar, &bank);
                    let simd = stage(variant, d, bits, backend, &bank);
                    let n = 19; // 16-tile + 3 remainder on AVX-512, 2×8 + 3 on AVX2
                    let x = rng.gaussian_vec_f32(n * d);
                    assert_backend_bitexact(&reference, &simd, &x, n, 7).unwrap_or_else(|e| {
                        panic!("{variant:?} d={d} bits={bits} backend={backend}: {e}")
                    });
                }
            }
        }
    }
}

#[test]
fn kernel_bitexact_ragged_and_random_shapes() {
    // randomized dims (non-multiples of the block size → scalar-finished
    // padded tails), batch sizes around the tile width, random gaps,
    // uniform quantizer included
    for backend in host_backends() {
        check(80, 0x2A6 ^ backend.name().len() as u64, |g| {
            let variant = *g.choose(&SIMD_VARIANTS);
            let d = g.usize_in(2, 300);
            let bits = g.usize_in(2, 4) as u8;
            let n = g.usize_in(0, 19);
            let gap = g.usize_in(0, 20);
            let bank = ParamBank::random(variant, d, g.rng.next_u64());
            let mut cfg_ref = Stage1Config::new(variant, d, bits);
            let mut cfg_simd = cfg_ref.clone().with_backend(backend);
            cfg_ref = cfg_ref.with_backend(KernelBackend::Scalar);
            if g.usize_in(0, 1) == 1 {
                cfg_ref.quant = QuantKind::Uniform;
                cfg_simd.quant = QuantKind::Uniform;
            }
            let reference = Stage1::with_bank(cfg_ref, bank.clone());
            let simd = Stage1::with_bank(cfg_simd, bank);
            let x = g.vec_f32(n * d, 2.0);
            assert_backend_bitexact(&reference, &simd, &x, n, gap)
                .map_err(|e| format!("{variant:?} d={d} bits={bits} n={n} {backend}: {e}"))
        });
    }
}

#[test]
fn kernel_bitexact_extreme_values() {
    // zero vectors, huge scales, tiny scales, and denormal-adjacent
    // inputs must take identical quantizer decisions on every backend
    for backend in host_backends() {
        for variant in SIMD_VARIANTS {
            let d = 128;
            let bank = ParamBank::random(variant, d, 9);
            let reference = stage(variant, d, 4, KernelBackend::Scalar, &bank);
            let simd = stage(variant, d, 4, backend, &bank);
            let mut rng = Rng::new(10);
            let cases: Vec<Vec<f32>> = vec![
                vec![0.0; d],
                vec![1e30; d],
                vec![1e-30; d],
                (0..d).map(|i| if i % 2 == 0 { 1e20 } else { -1e-20 }).collect(),
                rng.gaussian_vec_f32(d).iter().map(|v| v * 1e15).collect(),
            ];
            for (ci, x) in cases.iter().enumerate() {
                assert_backend_bitexact(&reference, &simd, x, 1, 0).unwrap_or_else(|e| {
                    panic!("{variant:?} case {ci} backend={backend}: {e}")
                });
            }
        }
    }
}

#[test]
fn kernel_f16_roundtrip_bitexact() {
    // the f16 execution-dtype model routes through roundtrip (scalar
    // math) but encode/decode of f16-sourced data must stay bit-exact
    // across backends
    let mut rng = Rng::new(0xF16);
    for backend in host_backends() {
        for variant in SIMD_VARIANTS {
            let d = 128;
            let n = 16;
            let bank = ParamBank::random(variant, d, 11);
            let reference = stage(variant, d, 4, KernelBackend::Scalar, &bank);
            let simd = stage(variant, d, 4, backend, &bank);
            let x: Vec<f32> = rng
                .gaussian_vec_f32(n * d)
                .iter()
                .map(|&v| f16::f16_bits_to_f32(f16::f32_to_f16_bits(v)))
                .collect();
            assert_backend_bitexact(&reference, &simd, &x, n, 3)
                .unwrap_or_else(|e| panic!("{variant:?} f16 backend={backend}: {e}"));
            // and the f16 batch roundtrip itself stays within tolerance
            let xh: Vec<u16> = x.iter().map(|&v| f16::f32_to_f16_bits(v)).collect();
            let mut out16 = vec![0u16; n * d];
            simd.roundtrip_batch_f16(&xh, &mut out16, n);
            let out16f: Vec<f32> = out16.iter().map(|&h| f16::f16_bits_to_f32(h)).collect();
            let mut out32 = vec![0.0f32; n * d];
            simd.roundtrip_batch(&x, &mut out32, n);
            assert!(mse(&out32, &out16f) < 1e-4, "{variant:?} f16 drift");
        }
    }
}

#[test]
fn f16_gather_output_is_converted_f32_decode() {
    // the f16 gather-output path must equal the f32 decode followed by
    // software f32→f16 conversion, elementwise, on every backend: F16C /
    // NEON hardware conversion rounds to nearest-even exactly like the
    // software reference, so the contract is bit-equality, not tolerance
    let mut rng = Rng::new(0xF16F);
    for backend in host_backends() {
        for (variant, d) in [
            (Variant::IsoFull, 128usize),
            (Variant::IsoFast, 126),  // ragged SO(4) tail
            (Variant::Planar2D, 64),
            (Variant::Rotor3D, 96),   // no native f16 tile → staged fallback
        ] {
            let bank = ParamBank::random(variant, d, 21);
            let s = stage(variant, d, 4, backend, &bank);
            let n = 19; // tile rows + scalar remainder rows
            let x = rng.gaussian_vec_f32(n * d);
            let mut sink = PackedSink::new();
            s.encode_batch(&x, n, &mut sink);
            let enc = s.encoded_len();
            let stride = enc + 5;
            let mut page = vec![0xEEu8; n * stride];
            for i in 0..n {
                page[i * stride..i * stride + enc].copy_from_slice(sink.encoded(i));
            }
            let mut scratch = BatchScratch::new();
            let mut out32 = vec![0.0f32; n * d];
            let mut out16 = vec![0u16; n * d];
            s.decode_batch_strided(&page, stride, n, &mut out32, &mut scratch);
            s.decode_batch_strided_f16(&page, stride, n, &mut out16, &mut scratch);
            for j in 0..n * d {
                assert_eq!(
                    out16[j],
                    f16::f32_to_f16_bits(out32[j]),
                    "{variant:?} d={d} backend={backend} at {j}"
                );
            }
        }
    }
}

#[test]
fn rotor3d_odd_intermediate_backend_bitexact() {
    // the OddIntermediate rotor kernel has SIMD arms of its own (unlike
    // the Multivector reference, which always runs scalar); like the
    // SO(4) kernels they must be bit-identical to the scalar path
    use isoquant::quant::pipeline::RotorImpl;
    let mut rng = Rng::new(0x30D);
    for backend in host_backends() {
        for d in [96usize, 100, 255] {
            let bank = ParamBank::random(Variant::Rotor3D, d, 0xB0B ^ d as u64);
            for bits in [2u8, 3, 4] {
                let mk = |b: KernelBackend| {
                    Stage1::with_bank(
                        Stage1Config::new(Variant::Rotor3D, d, bits)
                            .with_backend(b)
                            .with_rotor_impl(RotorImpl::OddIntermediate),
                        bank.clone(),
                    )
                };
                let reference = mk(KernelBackend::Scalar);
                let simd = mk(backend);
                let n = 19;
                let x = rng.gaussian_vec_f32(n * d);
                assert_backend_bitexact(&reference, &simd, &x, n, 3).unwrap_or_else(|e| {
                    panic!("Rotor3D/OddIntermediate d={d} bits={bits} backend={backend}: {e}")
                });
            }
        }
    }
}

#[test]
fn scalar_backend_selectable_and_reported() {
    // the reference stays runtime-selectable regardless of host SIMD
    let s = Stage1::new(
        Stage1Config::new(Variant::IsoFull, 128, 4).with_backend(KernelBackend::Scalar),
    );
    assert_eq!(s.kernel_backend(), Resolved::Scalar);
    let auto = Stage1::new(Stage1Config::new(Variant::IsoFull, 128, 4));
    // ISOQUANT_KERNEL may force scalar in CI; auto otherwise picks the
    // host's best — either way the resolved backend is reported
    let _ = auto.kernel_backend();
    // unsupported variants run scalar kernels under any backend without
    // error (dispatch falls through to the reference)
    let rotor = Stage1::new(
        Stage1Config::new(Variant::Rotor3D, 128, 3).with_backend(KernelBackend::Auto),
    );
    let mut out = vec![0.0f32; 128];
    let mut enc = Vec::new();
    let mut rng = Rng::new(12);
    let x = rng.gaussian_vec_f32(128);
    rotor.encode(&x, &mut enc);
    rotor.decode(&enc, &mut out);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn cache_pages_portable_across_backends() {
    // pages written by a SIMD-backed manager must decode identically
    // under a scalar-backed Stage1 (and vice versa): the on-disk/in-page
    // format is backend-invariant
    let mut rng = Rng::new(0xCAFE);
    for backend in host_backends() {
        let d = 64;
        let bank = ParamBank::random(Variant::IsoFull, d, 13);
        let writer = stage(Variant::IsoFull, d, 4, backend, &bank);
        let reader = stage(Variant::IsoFull, d, 4, KernelBackend::Scalar, &bank);
        let n = 10;
        let x = rng.gaussian_vec_f32(n * d);
        let mut sink = PackedSink::new();
        writer.encode_batch(&x, n, &mut sink);
        let mut scratch = BatchScratch::new();
        let mut via_writer = vec![0.0f32; n * d];
        let mut via_reader = vec![0.0f32; n * d];
        writer.decode_batch(sink.as_bytes(), n, &mut via_writer, &mut scratch);
        reader.decode_batch(sink.as_bytes(), n, &mut via_reader, &mut scratch);
        assert_eq!(
            via_writer.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_reader.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{backend}"
        );
    }
}
