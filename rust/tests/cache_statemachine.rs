//! State-machine property suite for the KV cache: random
//! admit/append/cancel/drop/evict-churn/spill/promote/compact
//! interleavings drive the flat index, the radix index pinned to its
//! v1 one-node-per-page shape, and the radix v2 cross-page-run shape
//! in lockstep against an unshared reference cache.  After every op:
//!
//!   * gathers are byte-identical across all four caches,
//!   * neither radix shape ever holds more pages than the flat index,
//!   * and on teardown every page ownership returns to zero.
//!
//! Cases optionally attach a persistent store per cache (tight budget
//! plus segment compaction on half of those) and end with a
//! persist → kill → reboot transition: the managers are dropped with
//! sequences still live (a crash, not a drain), fresh managers warm
//! boot from the same directories, and re-admissions must stay
//! byte-identical whatever coverage survived.
//!
//! The proplite harness shrinks any failure to a minimal forced tape;
//! `seeded_violation_shrinks_to_tiny_repro` pins that machinery by
//! planting a wrong invariant and asserting the repro collapses to a
//! handful of ops.  CI elevates case counts via `ISOQUANT_SM_CASES`.

use std::path::{Path, PathBuf};

use isoquant::kvcache::prefix::SCORE_SCALE;
use isoquant::kvcache::store::record::encode_record;
use isoquant::kvcache::store::record_len;
use isoquant::kvcache::{
    chain_key, CacheManager, GatherWorkspace, PageConfig, PageStore, PrefixIndexKind, StoreConfig,
};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::prng::Rng;
use isoquant::util::proplite::{check, find_counterexample, replay, Gen};

/// CI raises this via the `ISOQUANT_SM_CASES` env var (the
/// cache-statemachine leg runs 500+); local runs stay quick.
fn case_count(default: usize) -> usize {
    std::env::var("ISOQUANT_SM_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[derive(Clone, Copy)]
struct Geometry {
    cfg: PageConfig,
    bits: u8,
}

fn geometry(g: &mut Gen) -> Geometry {
    let dh = 4 * g.usize_in(4, 8); // 16..32, multiple of 4
    let bits = g.usize_in(2, 4) as u8;
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
    Geometry {
        cfg: PageConfig {
            tokens_per_page: g.usize_in(2, 5),
            n_layers: g.usize_in(1, 2),
            n_heads: 1,
            d_head: dh,
            encoded_len: stage1.encoded_len(),
        },
        bits,
    }
}

fn mk_cache(geo: &Geometry, max_pages: usize, sharing: bool, kind: PrefixIndexKind) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, geo.cfg.d_head, geo.bits));
    let mut m = CacheManager::new(stage1, geo.cfg, max_pages);
    m.prefix_sharing = sharing;
    m.index_kind = kind;
    m
}

/// Deterministic K/V for the token at position `t` of `stream`: seeded
/// by the chained hash of `stream[..=t]`, so equal prefixes produce
/// equal vectors — the property that makes prefixes shareable and a
/// slot copy byte-identical to a re-encode.
fn kv_at(stream: &[i32], t: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let seed = chain_key(None, &stream[..=t], 0xBEEF).0;
    let mut rng = Rng::new(seed);
    let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
    (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
}

fn kv_run(stream: &[i32], from: usize, to: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::new();
    let mut v = Vec::new();
    for t in from..to {
        let (tk, tv) = kv_at(stream, t, cfg);
        k.extend_from_slice(&tk);
        v.extend_from_slice(&tv);
    }
    (k, v)
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Gather `seq` from all four caches (batched path on radix-v2, the
/// per-vector oracle everywhere) and demand bit-identical results.
fn verify_seq(
    flat: &CacheManager,
    v1: &CacheManager,
    v2: &CacheManager,
    unshared: &CacheManager,
    seq: u64,
    len: usize,
    cfg: &PageConfig,
    ws: &mut GatherWorkspace,
) -> Result<(), String> {
    let t_max = len.max(1) + 2;
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut kb, mut vb) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    let (mut ku, mut vu) = (vec![9.0f32; sz], vec![9.0f32; sz]);
    let nb = v2
        .gather_ws(seq, t_max, &mut kb, &mut vb, ws)
        .map_err(|e| e.to_string())?;
    let nu = unshared
        .gather_reference(seq, t_max, &mut ku, &mut vu)
        .map_err(|e| e.to_string())?;
    if nb != len || nu != len {
        return Err(format!("seq {seq}: gather lengths {nb}/{nu} != {len}"));
    }
    let (ku, vu) = (bits_of(&ku), bits_of(&vu));
    if bits_of(&kb) != ku || bits_of(&vb) != vu {
        return Err(format!("seq {seq}: v2 batched gather != unshared reference"));
    }
    for (name, m) in [("v2", v2), ("v1", v1), ("flat", flat)] {
        let (mut k, mut v) = (vec![1.0f32; sz], vec![1.0f32; sz]);
        let n = m
            .gather_reference(seq, t_max, &mut k, &mut v)
            .map_err(|e| e.to_string())?;
        if n != len {
            return Err(format!("seq {seq}: {name} gathered {n} != {len}"));
        }
        if bits_of(&k) != ku || bits_of(&v) != vu {
            return Err(format!("seq {seq}: {name} gather != unshared reference"));
        }
    }
    Ok(())
}

fn store_dir(tag: &str, case: usize, which: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "isoquant-sm-{}-{tag}-{case}-{which}",
        std::process::id()
    ))
}

fn attach(m: &mut CacheManager, dir: &Path, budget: u64, compact: bool, seg_bytes: u64) {
    let mut sc = StoreConfig::for_cache(
        dir.to_path_buf(),
        m.fingerprint(),
        m.page_cfg().page_bytes(),
        budget,
    );
    if compact {
        // fractional score 2.0: rescue records whose prefixes were
        // adopted at least once, age out one-shot cold prompts
        sc = sc.with_compaction(2 * SCORE_SCALE as u32, 1 << 20);
        sc.segment_bytes = seg_bytes;
    }
    m.attach_store(PageStore::open(sc).unwrap());
}

/// The four caches driven in lockstep plus the shared op state.
struct Fleet {
    geo: Geometry,
    pool: usize,
    flat: CacheManager,
    v1: CacheManager,
    v2: CacheManager,
    unshared: CacheManager,
    live: Vec<(u64, Vec<i32>)>,
    bases: Vec<Vec<i32>>,
    next_seq: u64,
    next_tok: i32,
    dirs: Option<[PathBuf; 3]>,
    budget: u64,
    compact: bool,
    ws: GatherWorkspace,
}

impl Fleet {
    fn new(
        geo: Geometry,
        pool: usize,
        persist: bool,
        compact: bool,
        tag: &str,
        case: usize,
        bases: Vec<Vec<i32>>,
    ) -> Fleet {
        let flat = mk_cache(&geo, pool, true, PrefixIndexKind::Flat);
        let mut v1 = mk_cache(&geo, pool, true, PrefixIndexKind::Radix);
        v1.set_radix_max_run_pages(1);
        let v2 = mk_cache(&geo, pool, true, PrefixIndexKind::Radix);
        let unshared = mk_cache(&geo, 16_384, false, PrefixIndexKind::Flat);
        let rec = record_len(geo.cfg.tokens_per_page, geo.cfg.page_bytes()) as u64;
        let mut fleet = Fleet {
            geo,
            pool,
            flat,
            v1,
            v2,
            unshared,
            live: Vec::new(),
            bases,
            next_seq: 0,
            next_tok: 500_000,
            dirs: None,
            // compaction cases keep the budget tight enough that
            // segments really retire mid-run
            budget: if compact { 6 * rec } else { 0 },
            compact,
            ws: GatherWorkspace::new(),
        };
        if persist {
            let budget = fleet.budget;
            let dirs = [
                store_dir(tag, case, "flat"),
                store_dir(tag, case, "v1"),
                store_dir(tag, case, "v2"),
            ];
            for (m, d) in [&mut fleet.flat, &mut fleet.v1, &mut fleet.v2]
                .into_iter()
                .zip(&dirs)
            {
                let _ = std::fs::remove_dir_all(d);
                attach(m, d, budget, compact, 2 * rec);
            }
            fleet.dirs = Some(dirs);
        }
        fleet
    }

    fn shared(&mut self) -> [&mut CacheManager; 3] {
        [&mut self.flat, &mut self.v1, &mut self.v2]
    }

    /// Admit a (sometimes twisted) prefix of a base prompt into every
    /// cache; a no-op unless all three shared caches accept, so the
    /// page comparison always tracks identical loads.
    fn admit(&mut self, g: &mut Gen) -> Result<(), String> {
        let base = g.choose(&self.bases).clone();
        let plen = g.usize_in(1, base.len());
        let mut prompt = base[..plen].to_vec();
        if g.bool() && g.bool() {
            let i = g.usize_in(0, plen - 1);
            prompt[i] = self.next_tok;
            self.next_tok += 1;
        }
        self.admit_stream(prompt)
    }

    fn admit_stream(&mut self, prompt: Vec<i32>) -> Result<(), String> {
        if self
            .shared()
            .iter()
            .any(|m| !m.can_admit_prompt(&prompt, prompt.len()))
        {
            return Ok(());
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        let cfg = self.geo.cfg;
        for m in self.shared() {
            let reuse = m
                .start_seq_with_prompt(seq, &prompt)
                .map_err(|e| e.to_string())?;
            if reuse.tokens > prompt.len() {
                return Err(format!("reuse {} > prompt {}", reuse.tokens, prompt.len()));
            }
            let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
            m.append_run(seq, &k, &v, prompt.len() - reuse.tokens)
                .map_err(|e| format!("admitted but append failed: {e}"))?;
        }
        self.unshared.start_seq(seq).map_err(|e| e.to_string())?;
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        self.unshared
            .append_run(seq, &k, &v, prompt.len())
            .map_err(|e| e.to_string())?;
        self.live.push((seq, prompt));
        Ok(())
    }

    /// One decode token on a random live sequence.  Gated on the flat
    /// cache: radix never holds more pages, so whatever flat fits, the
    /// radix shapes must fit too.
    fn append(&mut self, g: &mut Gen) -> Result<(), String> {
        if self.live.is_empty() {
            return Ok(());
        }
        let i = g.usize_in(0, self.live.len() - 1);
        let tok = self.next_tok;
        self.next_tok += 1;
        let cfg = self.geo.cfg;
        let (seq, stream) = &mut self.live[i];
        let seq = *seq;
        stream.push(tok);
        let (k, v) = kv_at(stream, stream.len() - 1, &cfg);
        if self.flat.append_token(seq, &k, &v).is_err() {
            self.live[i].1.pop(); // pool exhausted: keep streams aligned
            return Ok(());
        }
        for (name, m) in [("v1", &mut self.v1), ("v2", &mut self.v2)] {
            m.append_token(seq, &k, &v)
                .map_err(|e| format!("{name} append failed where flat succeeded: {e}"))?;
        }
        self.unshared
            .append_token(seq, &k, &v)
            .map_err(|e| e.to_string())
    }

    fn drop_one(&mut self, g: &mut Gen) {
        if self.live.is_empty() {
            return;
        }
        let i = g.usize_in(0, self.live.len() - 1);
        let (seq, _) = self.live.swap_remove(i);
        for m in self.shared() {
            m.drop_seq(seq);
        }
        self.unshared.drop_seq(seq);
    }

    /// Client cancellation mid-prompt: admit, encode only half of the
    /// uncovered remainder, then tear down immediately — half-built
    /// CoW tails must release cleanly everywhere.
    fn cancel(&mut self, g: &mut Gen) -> Result<(), String> {
        let base = g.choose(&self.bases).clone();
        let plen = g.usize_in(1, base.len());
        let prompt = base[..plen].to_vec();
        if self
            .shared()
            .iter()
            .any(|m| !m.can_admit_prompt(&prompt, prompt.len()))
        {
            return Ok(());
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        let cfg = self.geo.cfg;
        for m in self.shared() {
            let reuse = m
                .start_seq_with_prompt(seq, &prompt)
                .map_err(|e| e.to_string())?;
            let half = reuse.tokens + (prompt.len() - reuse.tokens) / 2;
            let (k, v) = kv_run(&prompt, reuse.tokens, half, &cfg);
            m.append_run(seq, &k, &v, half - reuse.tokens)
                .map_err(|e| e.to_string())?;
            m.drop_seq(seq);
        }
        Ok(())
    }

    /// Spill barrier: every park enqueued so far becomes durable (and,
    /// with compaction configured, the compactor has run).
    fn flush(&mut self) {
        for m in self.shared() {
            m.flush_store();
        }
    }

    /// Park → spill → promote cycle: drop a sequence, drain the spill
    /// queue, then re-admit the same stream as a new sequence — the
    /// warm path must reassemble it from resident or cold pages.
    fn promote_cycle(&mut self, g: &mut Gen) -> Result<(), String> {
        if self.live.is_empty() {
            return Ok(());
        }
        let i = g.usize_in(0, self.live.len() - 1);
        let (seq, stream) = self.live.swap_remove(i);
        for m in self.shared() {
            m.drop_seq(seq);
        }
        self.unshared.drop_seq(seq);
        self.flush();
        self.admit_stream(stream)
    }

    /// Eviction churn: a cold one-page prompt admitted and dropped in
    /// one op — pressure that forces parked pages out of the pool.
    fn churn(&mut self) -> Result<(), String> {
        let tp = self.geo.cfg.tokens_per_page;
        let prompt: Vec<i32> = (0..tp as i32).map(|i| self.next_tok + i).collect();
        self.next_tok += tp as i32;
        if self
            .shared()
            .iter()
            .any(|m| !m.can_admit_prompt(&prompt, prompt.len()))
        {
            return Ok(());
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        let cfg = self.geo.cfg;
        for m in self.shared() {
            let reuse = m
                .start_seq_with_prompt(seq, &prompt)
                .map_err(|e| e.to_string())?;
            let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
            m.append_run(seq, &k, &v, prompt.len() - reuse.tokens)
                .map_err(|e| e.to_string())?;
            m.drop_seq(seq);
        }
        Ok(())
    }

    /// The sub-page index must never cost pages: identical op sequence,
    /// identical pool — both radix shapes stay at or below flat.
    fn check_pages(&self) -> Result<(), String> {
        for (name, m) in [("radix-v1", &self.v1), ("radix-v2", &self.v2)] {
            if m.pages_in_use() > self.flat.pages_in_use() {
                return Err(format!(
                    "{name} uses {} pages where flat uses {}",
                    m.pages_in_use(),
                    self.flat.pages_in_use()
                ));
            }
        }
        Ok(())
    }

    fn verify_one(&mut self, g: &mut Gen) -> Result<(), String> {
        if self.live.is_empty() {
            return Ok(());
        }
        let i = g.usize_in(0, self.live.len() - 1);
        let (seq, stream) = (self.live[i].0, self.live[i].1.len());
        let cfg = self.geo.cfg;
        verify_seq(
            &self.flat,
            &self.v1,
            &self.v2,
            &self.unshared,
            seq,
            stream,
            &cfg,
            &mut self.ws,
        )
    }

    fn verify_sweep(&mut self) -> Result<(), String> {
        let cfg = self.geo.cfg;
        for i in 0..self.live.len() {
            let (seq, len) = (self.live[i].0, self.live[i].1.len());
            verify_seq(
                &self.flat,
                &self.v1,
                &self.v2,
                &self.unshared,
                seq,
                len,
                &cfg,
                &mut self.ws,
            )?;
        }
        Ok(())
    }

    /// Crash and warm boot: drop every manager with sequences still
    /// live (no graceful drain — only previously parked pages are on
    /// disk), rebuild the fleet on the same store directories, and
    /// re-admit the old streams.  Whatever coverage survived, the
    /// gathers must stay byte-identical.
    fn reboot(mut self) -> Result<Fleet, String> {
        let dirs = match self.dirs.clone() {
            Some(d) => d,
            None => return Ok(self),
        };
        self.flush();
        let mut streams: Vec<Vec<i32>> = self.live.drain(..).map(|(_, s)| s).collect();
        streams.truncate(4);
        let (geo, pool, budget, compact) = (self.geo, self.pool, self.budget, self.compact);
        let next_seq = self.next_seq;
        drop(self); // the crash: managers (and store flocks) die here
        let rec = record_len(geo.cfg.tokens_per_page, geo.cfg.page_bytes()) as u64;
        let mut fleet = Fleet {
            flat: mk_cache(&geo, pool, true, PrefixIndexKind::Flat),
            v1: {
                let mut m = mk_cache(&geo, pool, true, PrefixIndexKind::Radix);
                m.set_radix_max_run_pages(1);
                m
            },
            v2: mk_cache(&geo, pool, true, PrefixIndexKind::Radix),
            unshared: mk_cache(&geo, 16_384, false, PrefixIndexKind::Flat),
            live: Vec::new(),
            bases: Vec::new(),
            next_seq,
            next_tok: 900_000,
            dirs: Some(dirs.clone()),
            budget,
            compact,
            geo,
            pool,
            ws: GatherWorkspace::new(),
        };
        for (m, d) in [&mut fleet.flat, &mut fleet.v1, &mut fleet.v2]
            .into_iter()
            .zip(&dirs)
        {
            attach(m, d, budget, compact, 2 * rec);
        }
        for stream in streams {
            fleet.admit_stream(stream)?;
        }
        fleet.check_pages()?;
        fleet.verify_sweep()?;
        Ok(fleet)
    }

    /// Drop everything and demand zero leaked ownerships and zero
    /// leaked live pages in every cache.
    fn teardown(mut self) -> Result<(), String> {
        for (seq, _) in std::mem::take(&mut self.live) {
            for m in self.shared() {
                m.drop_seq(seq);
            }
            self.unshared.drop_seq(seq);
        }
        for (name, m) in [("flat", &self.flat), ("radix-v1", &self.v1), ("radix-v2", &self.v2)] {
            if m.live_refs() != 0 {
                return Err(format!("{name}: {} refs leaked", m.live_refs()));
            }
            if m.live_pages() != 0 {
                return Err(format!("{name}: {} live pages leaked", m.live_pages()));
            }
        }
        if self.unshared.pages_in_use() != 0 {
            return Err("unshared cache leaked pages".into());
        }
        let dirs = self.dirs.take();
        drop(self); // release store flocks before deleting the dirs
        for d in dirs.into_iter().flatten() {
            let _ = std::fs::remove_dir_all(&d);
        }
        Ok(())
    }
}

fn base_prompts(g: &mut Gen, cfg: &PageConfig) -> Vec<Vec<i32>> {
    (0..3)
        .map(|b| {
            let n = g.usize_in(2 * cfg.tokens_per_page, 6 * cfg.tokens_per_page);
            (0..n).map(|i| (b * 1000 + i) as i32).collect()
        })
        .collect()
}

/// The core lockstep property (see module docs).
#[test]
fn prop_statemachine_lockstep_flat_v1_v2() {
    check(case_count(10), 0x57A7E3, |g| {
        let geo = geometry(g);
        let pool = g.usize_in(24, 96);
        let persist = g.bool();
        let compact = persist && g.bool();
        let bases = base_prompts(g, &geo.cfg);
        let mut fleet = Fleet::new(geo, pool, persist, compact, "lockstep", g.case, bases);
        let n_ops = g.usize_in(8, 28);
        for _ in 0..n_ops {
            match g.usize_in(0, 7) {
                0 | 1 => fleet.admit(g)?,
                2 => fleet.append(g)?,
                3 => fleet.drop_one(g),
                4 => fleet.cancel(g)?,
                5 => fleet.flush(),
                6 => fleet.promote_cycle(g)?,
                _ => fleet.churn()?,
            }
            fleet.check_pages()?;
            fleet.verify_one(g)?;
        }
        fleet.verify_sweep()?;
        if persist {
            fleet = fleet.reboot()?;
        }
        fleet.teardown()
    });
}

/// The shrinker itself, pinned on the real state machine: plant a
/// deliberately wrong invariant ("never more than 2 live sequences")
/// and require the minimal repro to collapse to at most 5 ops — three
/// admits are all it really takes.
#[test]
fn seeded_violation_shrinks_to_tiny_repro() {
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 1,
            n_heads: 1,
            d_head: 16,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 16, 2)).encoded_len(),
        },
        bits: 2,
    };
    let cfg = geo.cfg;
    let drive = |g: &mut Gen, executed: &mut usize| -> Result<(), String> {
        let mut m = mk_cache(&geo, 4096, true, PrefixIndexKind::Radix);
        let mut live: Vec<(u64, Vec<i32>)> = Vec::new();
        let mut next_seq = 0u64;
        let n_ops = g.usize_in(1, 24);
        for _ in 0..n_ops {
            *executed += 1;
            match g.usize_in(0, 3) {
                0 | 1 => {
                    let b = g.usize_in(0, 2) as i32;
                    let prompt: Vec<i32> = (0..6).map(|i| b * 100 + i).collect();
                    next_seq += 1;
                    let reuse = m
                        .start_seq_with_prompt(next_seq, &prompt)
                        .map_err(|e| e.to_string())?;
                    let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
                    m.append_run(next_seq, &k, &v, prompt.len() - reuse.tokens)
                        .map_err(|e| e.to_string())?;
                    live.push((next_seq, prompt));
                }
                2 if !live.is_empty() => {
                    let i = g.usize_in(0, live.len() - 1);
                    let (seq, stream) = &mut live[i];
                    stream.push(70_000 + *seq as i32);
                    let (k, v) = kv_at(stream, stream.len() - 1, &cfg);
                    m.append_token(*seq, &k, &v).map_err(|e| e.to_string())?;
                }
                3 if !live.is_empty() => {
                    let i = g.usize_in(0, live.len() - 1);
                    let (seq, _) = live.swap_remove(i);
                    m.drop_seq(seq);
                }
                _ => {}
            }
            // the seeded bug: this invariant is simply wrong
            if live.len() > 2 {
                return Err(format!("{} live sequences", live.len()));
            }
        }
        Ok(())
    };

    let cx = find_counterexample(40, 0x5EED, |g| {
        let mut n = 0;
        drive(g, &mut n)
    })
    .expect("the seeded violation must be found within 40 cases");
    let mut ops = 0usize;
    let verdict = replay(cx.case_seed, cx.case, &cx.tape, |g| drive(g, &mut ops));
    assert!(verdict.is_err(), "the shrunk tape must still reproduce the failure");
    assert!(
        ops <= 5,
        "shrunk repro executes {ops} ops (tape {:?}), want ≤ 5",
        cx.tape
    );
}

fn compat_geo() -> Geometry {
    Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 4)).encoded_len(),
        },
        bits: 4,
    }
}

fn plain_attach(m: &mut CacheManager, dir: &Path) {
    let sc = StoreConfig::for_cache(
        dir.to_path_buf(),
        m.fingerprint(),
        m.page_cfg().page_bytes(),
        0,
    );
    m.attach_store(PageStore::open(sc).unwrap());
}

fn verify_against_fresh(m: &mut CacheManager, geo: &Geometry, seq: u64, stream: &[i32]) {
    let cfg = geo.cfg;
    let mut un = mk_cache(geo, 4096, false, PrefixIndexKind::Flat);
    un.start_seq(seq).unwrap();
    let (k, v) = kv_run(stream, 0, stream.len(), &cfg);
    un.append_run(seq, &k, &v, stream.len()).unwrap();
    let t_max = stream.len();
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut km, mut vm) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    let (mut ku, mut vu) = (vec![1.0f32; sz], vec![1.0f32; sz]);
    m.gather(seq, t_max, &mut km, &mut vm).unwrap();
    un.gather(seq, t_max, &mut ku, &mut vu).unwrap();
    assert_eq!(bits_of(&km), bits_of(&ku), "K diverged from fresh encode");
    assert_eq!(bits_of(&vm), bits_of(&vu), "V diverged from fresh encode");
}

/// Sub-run records cross the index boundary: a radix-v2 writer parks a
/// page whose node run starts mid-page (a divergent suffix assembled
/// over a shared head slot).  The spilled record is padded to the page
/// boundary, so (a) a flat warm boot finds it under the standard
/// page-aligned chain key and rehydrates FULL coverage, and (b) a
/// radix-v2 warm boot promotes it and counts the sub-run provenance.
#[test]
fn subrun_records_warm_boot_under_both_indexes() {
    let geo = compat_geo();
    let cfg = geo.cfg;
    let dir = store_dir("subrun-compat", 0, "v2");
    let _ = std::fs::remove_dir_all(&dir);
    let prompt_a: Vec<i32> = (0..12).collect();
    let mut prompt_b = prompt_a.clone();
    prompt_b[5] = 777; // diverges mid-page 1: B's page-1 run starts at slot 1
    {
        let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
        plain_attach(&mut m, &dir);
        for (seq, prompt) in [(1u64, &prompt_a), (2, &prompt_b)] {
            let reuse = m.start_seq_with_prompt(seq, prompt).unwrap();
            let (k, v) = kv_run(prompt, reuse.tokens, prompt.len(), &cfg);
            m.append_run(seq, &k, &v, prompt.len() - reuse.tokens).unwrap();
        }
        m.drop_seq(1);
        m.drop_seq(2);
        m.flush_store();
        // A's three pages, B's CoW page 1 (the mid-page run) and page 2
        assert_eq!(m.share.pages_spilled, 5, "mid-page runs must spill too");
        assert!(
            m.store().unwrap().stats().spilled >= 5,
            "store must have accepted every record"
        );
    }
    // radix-v2 reader first (a reader's own re-spills rewrite records
    // with start_slot 0, so the provenance assertion must come first)
    {
        let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
        plain_attach(&mut m, &dir);
        let reuse = m.start_seq_with_prompt(3, &prompt_b).unwrap();
        assert_eq!(reuse.tokens, 12, "v2 warm boot must cover the whole prompt");
        assert!(
            m.share.subrun_promotions >= 1,
            "page 1 promoted from a padded sub-run record"
        );
        verify_against_fresh(&mut m, &geo, 3, &prompt_b);
        m.drop_seq(3);
        assert_eq!(m.live_refs(), 0);
        m.flush_store();
    }
    // flat reader: the padded record answers the standard page-aligned
    // chain key, so the "other" index gets full coverage too
    {
        let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Flat);
        plain_attach(&mut m, &dir);
        for (seq, prompt) in [(4u64, &prompt_b), (5, &prompt_a)] {
            let reuse = m.start_seq_with_prompt(seq, prompt).unwrap();
            assert_eq!(
                reuse.tokens,
                prompt.len(),
                "flat warm boot must cover the whole prompt"
            );
            verify_against_fresh(&mut m, &geo, seq, prompt);
            m.drop_seq(seq);
        }
        assert_eq!(m.live_refs(), 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Stale and corrupt sub-run records must read as plain misses: the
/// boot re-encodes from scratch and stays byte-identical — never a
/// crash, never a wrong gather.
#[test]
fn stale_or_corrupt_subrun_records_are_misses() {
    let geo = compat_geo();
    let cfg = geo.cfg;
    let prompt: Vec<i32> = (0..8).map(|i| 40 + i).collect();
    let probe = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
    let fingerprint = probe.fingerprint();
    let page_bytes = cfg.page_bytes();
    let key0 = chain_key(None, &prompt[..4], fingerprint);

    // (a) stale: a well-formed v2 record under the right chain key but
    // carrying the WRONG token run (content drifted) — the identity
    // check must reject it and the walk must fall back to re-encoding.
    // The directory is rebuilt per reader: a reader's own teardown
    // spills real records, which would hand the next reader a warm hit
    let mut buf = Vec::new();
    let wrong_run: Vec<i32> = prompt[..4].iter().rev().copied().collect();
    let zero_page = vec![0u8; page_bytes];
    encode_record(
        &mut buf,
        key0,
        None,
        fingerprint,
        &wrong_run,
        &zero_page,
        2,
        7 * SCORE_SCALE as u32,
    );
    for kind in [PrefixIndexKind::Radix, PrefixIndexKind::Flat] {
        let stale_dir = store_dir("subrun-stale", 0, kind.name());
        let _ = std::fs::remove_dir_all(&stale_dir);
        std::fs::create_dir_all(&stale_dir).unwrap();
        std::fs::write(stale_dir.join("seg-00000000.iqs"), &buf).unwrap();
        let mut m = mk_cache(&geo, 64, true, kind);
        plain_attach(&mut m, &stale_dir);
        assert_eq!(m.cold_pages(), 1, "{kind:?}: the stale record scans fine");
        let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
        assert_eq!(reuse.tokens, 0, "{kind:?}: stale sub-run record must miss");
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        m.append_run(1, &k, &v, prompt.len()).unwrap();
        verify_against_fresh(&mut m, &geo, 1, &prompt);
        m.drop_seq(1);
        drop(m);
        let _ = std::fs::remove_dir_all(&stale_dir);
    }

    // (b) corrupt: same record with one bit flipped inside the v2
    // extension — the CRC covers the extension, so the scan drops the
    // record and the boot starts cold
    let corrupt_dir = store_dir("subrun-corrupt", 0, "v2");
    let _ = std::fs::remove_dir_all(&corrupt_dir);
    std::fs::create_dir_all(&corrupt_dir).unwrap();
    let mut bad = buf.clone();
    bad[44] ^= 0x01; // first byte of the start_slot extension
    std::fs::write(corrupt_dir.join("seg-00000000.iqs"), &bad).unwrap();
    let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
    plain_attach(&mut m, &corrupt_dir);
    assert_eq!(m.cold_pages(), 0, "corrupt extension must not survive the scan");
    let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
    assert_eq!(reuse.tokens, 0);
    let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
    m.append_run(1, &k, &v, prompt.len()).unwrap();
    verify_against_fresh(&mut m, &geo, 1, &prompt);
    m.drop_seq(1);
    let _ = std::fs::remove_dir_all(&corrupt_dir);
}

/// Pre/post-compaction cross-index compatibility: a radix-v2 writer
/// under a tight budget churns cold prompts until its oldest segments
/// retire; the compactor must rescue the much-reused hot root, and
/// both a flat and a radix warm boot must still rehydrate it
/// byte-identically.  Compaction-off on the same schedule loses it.
#[test]
fn compaction_preserves_hot_roots_across_index_boundaries() {
    let geo = compat_geo();
    let cfg = geo.cfg;
    let tp = cfg.tokens_per_page;
    let hot: Vec<i32> = (0..tp as i32).collect();
    let rec = record_len(tp, cfg.page_bytes()) as u64;
    for compact in [true, false] {
        let dir = store_dir("compact-compat", usize::from(compact), "v2");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut m = mk_cache(&geo, 64, true, PrefixIndexKind::Radix);
            let mut sc = StoreConfig::for_cache(
                dir.to_path_buf(),
                m.fingerprint(),
                cfg.page_bytes(),
                3 * rec,
            );
            sc.segment_bytes = rec; // one record per segment
            if compact {
                // fractional score 2.0: the hot root (3 adoptions →
                // score 4.0) clears it, one-shot cold prompts (1.0) age
                sc = sc.with_compaction(2 * SCORE_SCALE as u32, 1 << 20);
            }
            m.attach_store(PageStore::open(sc).unwrap());
            // the hot root: adopted by three followers before parking
            for seq in 1..=4u64 {
                let reuse = m.start_seq_with_prompt(seq, &hot).unwrap();
                let (k, v) = kv_run(&hot, reuse.tokens, hot.len(), &cfg);
                m.append_run(seq, &k, &v, hot.len() - reuse.tokens).unwrap();
            }
            for seq in 1..=4u64 {
                m.drop_seq(seq);
            }
            m.flush_store();
            // cold churn: each unique prompt spills one record, and the
            // tight budget retires the oldest segment every time
            for c in 0..5u64 {
                let seq = 100 + c;
                let prompt: Vec<i32> = (0..tp as i32).map(|i| 9_000 + c as i32 * 100 + i).collect();
                let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
                let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
                m.append_run(seq, &k, &v, prompt.len() - reuse.tokens).unwrap();
                m.drop_seq(seq);
                m.flush_store();
            }
            let st = m.store().unwrap().stats();
            if compact {
                assert!(st.records_compacted >= 1, "the hot root must be rescued");
                assert!(st.segments_compacted >= 1);
                m.note_store_health();
                assert!(m.share.records_compacted >= 1, "stats mirrored into the share line");
            } else {
                assert_eq!(st.records_compacted, 0);
            }
        }
        // warm boot under BOTH indexes with a generous budget
        for kind in [PrefixIndexKind::Flat, PrefixIndexKind::Radix] {
            let mut m = mk_cache(&geo, 64, true, kind);
            plain_attach(&mut m, &dir);
            let reuse = m.start_seq_with_prompt(1, &hot).unwrap();
            if compact {
                assert_eq!(
                    reuse.tokens,
                    hot.len(),
                    "{kind:?}: the rescued hot root must warm boot fully"
                );
            } else {
                assert_eq!(
                    reuse.tokens, 0,
                    "{kind:?}: without compaction FIFO retirement lost the root"
                );
            }
            let (k, v) = kv_run(&hot, reuse.tokens, hot.len(), &cfg);
            m.append_run(1, &k, &v, hot.len() - reuse.tokens).unwrap();
            verify_against_fresh(&mut m, &geo, 1, &hot);
            m.drop_seq(1);
            assert_eq!(m.live_refs(), 0, "{kind:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
