//! Serve smoke: N concurrent same-prefix clients against the real TCP
//! server must receive byte-identical token streams, with prefix
//! sharing on and off — and the two runs must agree with each other
//! (sharing is an allocator optimization, never a semantic one).
//!
//! Needs `make artifacts`; SKIPS (passes trivially, with a note) when
//! artifacts are absent so `cargo test` works in a fresh checkout.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use isoquant::config::EngineConfig;
use isoquant::coordinator::Engine;
use isoquant::runtime::ServingModel;
use isoquant::server::{serve_on, Client};

/// The XLA CPU runtime does not tolerate concurrent PJRT client
/// creation in one process; serialize everything that touches PJRT.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = isoquant::runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts not built; skipping serve smoke test");
        None
    }
}

/// Boot a server (engine on its own thread — the PJRT client is !Send,
/// so it must be created where it runs), fire all clients concurrently,
/// and return (per-client token streams, per-client prefix_hit_pages)
/// in client order.
fn run_serve(
    dir: &PathBuf,
    prefix_sharing: bool,
    prompts: &[Vec<i32>],
    persist_dir: Option<&std::path::Path>,
) -> (Vec<Vec<i32>>, Vec<usize>) {
    // bind before spawning: client connects queue in the backlog even
    // if the accept loop isn't polling yet
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let dir_srv = dir.clone();
    let persist = persist_dir.map(|p| p.to_string_lossy().into_owned());
    let server = std::thread::spawn(move || {
        let model = ServingModel::load(&dir_srv).expect("load model");
        let mut cfg = EngineConfig::default();
        cfg.prefix_sharing = prefix_sharing;
        if let Some(p) = persist {
            cfg.persist_dir = p;
        }
        let engine = Engine::new(model, cfg).expect("boot engine");
        serve_on(engine, listener, stop_srv).expect("serve")
    });

    let clients: Vec<_> = prompts
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, prompt)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let v = c
                    .generate(i as u64 + 1, &prompt, 8)
                    .expect("generate");
                let toks: Vec<i32> = v
                    .get("tokens")
                    .expect("tokens field")
                    .as_arr()
                    .expect("tokens array")
                    .iter()
                    .map(|t| t.as_f64().unwrap() as i32)
                    .collect();
                let hits = v
                    .get("prefix_hit_pages")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(0);
                (toks, hits)
            })
        })
        .collect();
    let results: Vec<(Vec<i32>, usize)> =
        clients.into_iter().map(|j| j.join().unwrap()).collect();
    stop.store(true, Ordering::SeqCst);
    let report = server.join().unwrap();
    // a healthy run with patient clients exercises none of the
    // lifecycle escape hatches — and the drain must leave no lane behind
    assert_eq!(report.share.requests_cancelled, 0, "spurious cancellations");
    assert_eq!(report.share.requests_timed_out, 0, "spurious timeouts");
    assert_eq!(report.share.requests_shed, 0, "spurious shedding");
    assert_eq!(report.share.store_degraded, 0, "store degraded during smoke");
    assert_eq!(report.undrained_lanes, 0, "drain left lanes active");
    assert_eq!(report.requests as usize, prompts.len(), "request count");
    results.into_iter().unzip()
}

#[test]
fn same_prefix_clients_get_identical_completions_sharing_on_and_off() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    // 2× the lane count of same-prompt clients: the first wave is cold;
    // the second can only be admitted after a first-wave lane finished,
    // by which time the prefix pages are published — so it must hit
    let lanes = isoquant::runtime::Manifest::load(&dir)
        .expect("manifest")
        .model
        .serve_batch;
    let prompt: Vec<i32> = (0..24).map(|i| (i * 7) % 50 + 1).collect();
    let prompts = vec![prompt; lanes * 2];

    let (on_tokens, on_hits) = run_serve(&dir, true, &prompts, None);
    let (off_tokens, off_hits) = run_serve(&dir, false, &prompts, None);

    // every client sees the same completion within a run...
    for (i, t) in on_tokens.iter().enumerate() {
        assert!(!t.is_empty(), "client {i} got no tokens (sharing on)");
        assert_eq!(t, &on_tokens[0], "client {i} diverged (sharing on)");
    }
    for (i, t) in off_tokens.iter().enumerate() {
        assert_eq!(t, &off_tokens[0], "client {i} diverged (sharing off)");
    }
    // ...and sharing must not change a single token
    assert_eq!(on_tokens[0], off_tokens[0], "sharing changed the output");

    // sharing off never reports hits; sharing on reports hits for the
    // late wave (2× lanes clients can't all be admitted cold)
    assert!(off_hits.iter().all(|&h| h == 0));
    assert!(
        on_hits.iter().sum::<usize>() > 0,
        "no prefix hits across {} same-prompt clients: {on_hits:?}",
        prompts.len()
    );
}

/// Restart rehydration against the real TCP server: a second boot on
/// the same `persist_dir` must adopt the first boot's prompt pages
/// (every client reports `prefix_hit_pages > 0` — even the very first
/// admission, which can only be served by the rehydrated store) and
/// produce byte-identical completions, which must also match a run
/// that never persisted anything.
#[test]
fn restart_on_same_persist_dir_rehydrates_and_matches() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let lanes = isoquant::runtime::Manifest::load(&dir)
        .expect("manifest")
        .model
        .serve_batch;
    let persist = std::env::temp_dir().join(format!(
        "isoquant-serve-persist-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&persist);
    let prompt: Vec<i32> = (0..24).map(|i| (i * 5) % 40 + 1).collect();
    let prompts = vec![prompt; lanes.max(2)];

    // boot 1 populates the store; boot 2 must warm-start from it
    let (cold_tokens, _) = run_serve(&dir, true, &prompts, Some(persist.as_path()));
    let (warm_tokens, warm_hits) = run_serve(&dir, true, &prompts, Some(persist.as_path()));
    // a run that never persisted anything is the semantic reference
    let (plain_tokens, _) = run_serve(&dir, true, &prompts, None);

    for (i, t) in cold_tokens.iter().enumerate() {
        assert!(!t.is_empty(), "client {i} got no tokens (cold boot)");
    }
    assert_eq!(cold_tokens, warm_tokens, "restart changed completions");
    assert_eq!(cold_tokens, plain_tokens, "persistence changed completions");
    // the warm boot serves the prefix from disk: every client —
    // including the first admission, before anything was published in
    // RAM — adopts rehydrated pages
    assert!(
        warm_hits.iter().all(|&h| h > 0),
        "a post-restart client missed the rehydrated prefix: {warm_hits:?}"
    );
    let _ = std::fs::remove_dir_all(&persist);
}
