//! Property-based integration tests over the quantization stack and the
//! coordinator substrates (proplite harness; each failure prints a
//! replayable per-case seed).

use isoquant::kvcache::{CacheManager, GatherWorkspace, PageConfig};
use isoquant::math::quaternion as quat;
use isoquant::quant::packing;
use isoquant::quant::{
    mse, BatchScratch, PackedSink, ParamBank, QuantKind, Stage1, Stage1Config, Variant,
};
use isoquant::util::pool::ParallelPolicy;
use isoquant::util::prng::Rng;
use isoquant::util::proplite::{assert_close, check};

const VARIANTS: [Variant; 6] = [
    Variant::IsoFull,
    Variant::IsoFast,
    Variant::Planar2D,
    Variant::Rotor3D,
    Variant::Dense,
    Variant::Grouped8D,
];

#[test]
fn prop_roundtrip_bounded_error_all_variants() {
    // for any variant / d / bits / scale, stage-1 reconstruction keeps a
    // bounded relative error and never produces non-finite values
    check(150, 0xA11CE, |g| {
        let variant = *g.choose(&VARIANTS);
        let d = if variant == Variant::Dense {
            g.usize_in(2, 96) // dense is O(d²); keep property cases small
        } else {
            g.usize_in(2, 512)
        };
        let bits = g.usize_in(2, 4) as u8;
        let scale = g.f32_in(0.01, 100.0);
        let x = g.vec_f32(d, scale);
        let s = Stage1::new(Stage1Config::new(variant, d, bits));
        let mut out = vec![0.0f32; d];
        s.roundtrip(&x, &mut out);
        if out.iter().any(|v| !v.is_finite()) {
            return Err(format!("{variant:?} d={d} b={bits}: non-finite output"));
        }
        let power = x.iter().map(|&v| (v * v) as f64).sum::<f64>().max(1e-12);
        let err = x
            .iter()
            .zip(&out)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>();
        // stage-1 of a *normalized* vector can at worst lose all energy
        // (err/power ≈ 1) but must never blow up beyond the double cover
        // of the sphere radius
        if err / power > 4.0 {
            return Err(format!(
                "{variant:?} d={d} b={bits}: rel err {} too large",
                err / power
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_encode_decode_equals_fused_roundtrip() {
    check(120, 0xB0B, |g| {
        let variant = *g.choose(&VARIANTS);
        let d = if variant == Variant::Dense {
            g.usize_in(2, 64)
        } else {
            g.usize_in(2, 256)
        };
        let bits = g.usize_in(2, 4) as u8;
        let x = g.vec_f32(d, 1.0);
        let s = Stage1::new(Stage1Config::new(variant, d, bits));
        let mut fused = vec![0.0f32; d];
        s.roundtrip(&x, &mut fused);
        let mut bytes = Vec::new();
        s.encode(&x, &mut bytes);
        if bytes.len() != s.encoded_len() {
            return Err(format!(
                "{variant:?}: encoded {} bytes, expected {}",
                bytes.len(),
                s.encoded_len()
            ));
        }
        let mut decoded = vec![0.0f32; d];
        s.decode(&bytes, &mut decoded);
        assert_close(&fused, &decoded, 1e-5, 1e-4)
            .map_err(|e| format!("{variant:?} d={d} b={bits}: {e}"))
    });
}

/// Compare batch encode/decode against the per-vector reference for one
/// `(stage1, x)` case, requiring *bit* equality (f32-to_bits) of decodes
/// and byte equality of encodes.  Also exercises the strided decode with
/// a randomized inter-record gap (a simulated ragged tail page).
fn assert_batch_bitexact(
    s: &Stage1,
    x: &[f32],
    n: usize,
    gap: usize,
    sink: &mut PackedSink,
    scratch: &mut BatchScratch,
) -> Result<(), String> {
    let d = s.d();
    let enc = s.encoded_len();
    s.encode_batch(x, n, sink);
    let mut reference = Vec::new();
    for i in 0..n {
        s.encode(&x[i * d..(i + 1) * d], &mut reference);
    }
    if sink.as_bytes() != &reference[..] {
        return Err("encode_batch bytes differ from per-vector encode".into());
    }
    // contiguous batch decode vs per-vector decode
    let mut got = vec![0.0f32; n * d];
    s.decode_batch(sink.as_bytes(), n, &mut got, scratch);
    let mut want = vec![0.0f32; n * d];
    for i in 0..n {
        s.decode(&reference[i * enc..(i + 1) * enc], &mut want[i * d..(i + 1) * d]);
    }
    for j in 0..n * d {
        if got[j].to_bits() != want[j].to_bits() {
            return Err(format!(
                "decode_batch not bit-exact at {j}: {} vs {}",
                got[j], want[j]
            ));
        }
    }
    // strided decode over a ragged page image (garbage in the gaps)
    if n > 0 {
        let stride = enc + gap;
        let mut page = vec![0xEEu8; n * stride];
        for i in 0..n {
            page[i * stride..i * stride + enc].copy_from_slice(sink.encoded(i));
        }
        let mut strided = vec![0.0f32; n * d];
        s.decode_batch_strided(&page, stride, n, &mut strided, scratch);
        for j in 0..n * d {
            if strided[j].to_bits() != want[j].to_bits() {
                return Err(format!("strided decode not bit-exact at {j}"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_batch_bitexact_full_table2_sweep() {
    // the acceptance sweep: every variant × d ∈ {128, 256, 512} × bits ∈
    // {2, 3, 4}, bit-exact in both directions, plus a ragged (n not a
    // multiple of anything) strided layout per point
    let mut rng = Rng::new(0xBA7C);
    let mut sink = PackedSink::new();
    let mut scratch = BatchScratch::new();
    for variant in VARIANTS {
        for d in [128usize, 256, 512] {
            // one parameter bank per (variant, d): Dense banks are O(d³)
            // to sample, so share them across bit widths
            let bank = ParamBank::random(variant, d, 0x5EED ^ d as u64);
            for bits in [2u8, 3, 4] {
                let s = Stage1::with_bank(Stage1Config::new(variant, d, bits), bank.clone());
                let n = 5;
                let x = rng.gaussian_vec_f32(n * d);
                assert_batch_bitexact(&s, &x, n, 7, &mut sink, &mut scratch)
                    .unwrap_or_else(|e| panic!("{variant:?} d={d} bits={bits}: {e}"));
            }
        }
    }
}

#[test]
fn prop_batch_bitexact_random_shapes() {
    // randomized dims (including non-multiples of the block size →
    // padded tail codes), batch sizes, and strided gaps
    check(60, 0xB17E, |g| {
        let variant = *g.choose(&VARIANTS);
        let d = if variant == Variant::Dense {
            g.usize_in(2, 48)
        } else {
            g.usize_in(2, 200)
        };
        let bits = g.usize_in(2, 4) as u8;
        let n = g.usize_in(0, 12);
        let gap = g.usize_in(0, 20);
        let s = Stage1::new(Stage1Config::new(variant, d, bits));
        let x = g.vec_f32(n * d, 2.0);
        let mut sink = PackedSink::new();
        let mut scratch = BatchScratch::new();
        assert_batch_bitexact(&s, &x, n, gap, &mut sink, &mut scratch)
            .map_err(|e| format!("{variant:?} d={d} bits={bits} n={n}: {e}"))
    });
}

#[test]
fn prop_batched_gather_bitexact_vs_reference_gather() {
    // random cache states: the strip-parallel batched gather must equal
    // the retained per-vector reference gather bit for bit, and ragged
    // tail pages (len % tokens_per_page != 0) must round-trip
    check(25, 0x6A7E, |g| {
        let dh = 4 * g.usize_in(1, 16); // 4..64
        let bits = g.usize_in(2, 4) as u8;
        let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
        let cfg = PageConfig {
            tokens_per_page: g.usize_in(1, 7),
            n_layers: g.usize_in(1, 3),
            n_heads: g.usize_in(1, 4),
            d_head: dh,
            encoded_len: stage1.encoded_len(),
        };
        let mut mgr = CacheManager::new(stage1, cfg, 512);
        mgr.parallel = *g.choose(&[
            ParallelPolicy::Off,
            ParallelPolicy::Auto,
            ParallelPolicy::Fixed(2),
        ]);
        mgr.start_seq(1).map_err(|e| e.to_string())?;
        let tok_n = cfg.n_layers * cfg.n_heads * dh;
        let len = g.usize_in(0, 3 * cfg.tokens_per_page + 1); // ragged tails likely
        for _ in 0..len {
            let k = g.vec_f32(tok_n, 1.0);
            let v = g.vec_f32(tok_n, 1.0);
            mgr.append_token(1, &k, &v).map_err(|e| e.to_string())?;
        }
        let t_max = len + g.usize_in(0, 4);
        let sz = cfg.n_layers * cfg.n_heads * t_max * dh;
        let (mut ka, mut va) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let (mut kb, mut vb) = (vec![3.0f32; sz], vec![3.0f32; sz]);
        let mut ws = GatherWorkspace::new();
        let na = mgr
            .gather_reference(1, t_max, &mut ka, &mut va)
            .map_err(|e| e.to_string())?;
        let nb = mgr
            .gather_ws(1, t_max, &mut kb, &mut vb, &mut ws)
            .map_err(|e| e.to_string())?;
        if na != nb {
            return Err(format!("token counts differ: {na} vs {nb}"));
        }
        for (name, a, b) in [("K", &ka, &kb), ("V", &va, &vb)] {
            for j in 0..sz {
                if a[j].to_bits() != b[j].to_bits() {
                    return Err(format!(
                        "{name} not bit-exact at {j} ({} vs {}, policy {:?})",
                        a[j], b[j], mgr.parallel
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_quantizer_also_roundtrips() {
    check(60, 0xC0DE, |g| {
        let variant = *g.choose(&[Variant::IsoFull, Variant::Planar2D, Variant::Rotor3D]);
        let d = g.usize_in(2, 256);
        let bits = g.usize_in(2, 4) as u8;
        let mut cfg = Stage1Config::new(variant, d, bits);
        cfg.quant = QuantKind::Uniform;
        let s = Stage1::new(cfg);
        let x = g.vec_f32(d, 2.0);
        let mut fused = vec![0.0f32; d];
        s.roundtrip(&x, &mut fused);
        let mut bytes = Vec::new();
        s.encode(&x, &mut bytes);
        let mut decoded = vec![0.0f32; d];
        s.decode(&bytes, &mut decoded);
        assert_close(&fused, &decoded, 1e-5, 1e-4).map_err(|e| format!("{variant:?}: {e}"))
    });
}

#[test]
fn prop_packing_roundtrip_arbitrary() {
    check(300, 0xFACADE, |g| {
        let bits = g.usize_in(2, 4) as u8;
        let n = g.usize_in(0, 700);
        let codes: Vec<u8> = (0..n)
            .map(|_| (g.rng.below(1usize << bits)) as u8)
            .collect();
        let mut packed = Vec::new();
        packing::pack(&codes, bits, &mut packed);
        if packed.len() != packing::packed_len(n, bits) {
            return Err("packed length mismatch".into());
        }
        let mut back = Vec::new();
        packing::unpack(&packed, bits, n, &mut back);
        if back != codes {
            return Err(format!("roundtrip failed at bits={bits} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rotation_isometry_before_quantization() {
    // with "infinite" bits (identity quantizer approximated by 4-bit at
    // tiny amplitudes... instead test the rotation layer directly): any
    // quaternion pair sandwich preserves norms of random 4-vectors
    check(300, 0x150, |g| {
        let ql = g.rng.haar_quaternion();
        let qr = g.rng.haar_quaternion();
        let v: [f32; 4] = std::array::from_fn(|_| g.rng.gaussian() as f32);
        let y = quat::sandwich(ql, v, qr);
        let nv = quat::norm(v);
        let ny = quat::norm(y);
        if (nv - ny).abs() > 1e-4 * nv.max(1.0) {
            return Err(format!("norm not preserved: {nv} vs {ny}"));
        }
        let back = quat::sandwich_inv(ql, y, qr);
        assert_close(&back, &v, 1e-5, 1e-4)
    });
}

#[test]
fn prop_param_bank_interpolation_on_manifold() {
    check(80, 0x51E2, |g| {
        let d = g.usize_in(4, 128) & !3;
        let d = d.max(4);
        let variant = *g.choose(&[Variant::IsoFull, Variant::IsoFast]);
        let a = ParamBank::random(variant, d, g.rng.next_u64());
        let b = ParamBank::random(variant, d, g.rng.next_u64());
        let t = g.f32_in(0.0, 1.0);
        let mid = a.interpolate(&b, t);
        for q in mid.q_l.iter().chain(&mid.q_r) {
            let n = quat::norm(*q);
            if (n - 1.0).abs() > 1e-4 {
                return Err(format!("interpolated quaternion norm {n}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cache_manager_random_ops_vs_reference() {
    // random append/gather/drop schedule against a plain Vec reference
    check(30, 0xCACE, |g| {
        let dh = 8 * g.usize_in(1, 4); // 8..32
        let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, 4));
        let cfg = PageConfig {
            tokens_per_page: g.usize_in(1, 8),
            n_layers: g.usize_in(1, 2),
            n_heads: g.usize_in(1, 3),
            d_head: dh,
            encoded_len: stage1.encoded_len(),
        };
        let mut mgr = CacheManager::new(stage1, cfg, 256);
        let mut reference: std::collections::HashMap<u64, Vec<(Vec<f32>, Vec<f32>)>> =
            std::collections::HashMap::new();
        let tok_n = cfg.n_layers * cfg.n_heads * dh;
        let mut live: Vec<u64> = Vec::new();
        let mut next_seq = 0u64;
        for _ in 0..40 {
            match g.usize_in(0, 3) {
                0 => {
                    // start
                    next_seq += 1;
                    mgr.start_seq(next_seq).map_err(|e| e.to_string())?;
                    reference.insert(next_seq, Vec::new());
                    live.push(next_seq);
                }
                1 if !live.is_empty() => {
                    // append
                    let s = *g.choose(&live);
                    let k = g.vec_f32(tok_n, 1.0);
                    let v = g.vec_f32(tok_n, 1.0);
                    mgr.append_token(s, &k, &v).map_err(|e| e.to_string())?;
                    reference.get_mut(&s).unwrap().push((k, v));
                }
                2 if !live.is_empty() => {
                    // drop
                    let idx = g.rng.below(live.len());
                    let s = live.swap_remove(idx);
                    mgr.drop_seq(s);
                    reference.remove(&s);
                }
                _ if !live.is_empty() => {
                    // gather & verify token count + reconstruction quality
                    let s = *g.choose(&live);
                    let want = &reference[&s];
                    let t_max = want.len().max(1) + g.usize_in(0, 3);
                    let sz = cfg.n_layers * cfg.n_heads * t_max * dh;
                    let mut k_out = vec![0.0f32; sz];
                    let mut v_out = vec![0.0f32; sz];
                    let n = mgr
                        .gather(s, t_max, &mut k_out, &mut v_out)
                        .map_err(|e| e.to_string())?;
                    if n != want.len().min(t_max) {
                        return Err(format!("gather count {n} != {}", want.len()));
                    }
                    // spot-check one (token, layer, head) reconstruction
                    if n > 0 {
                        let t = g.rng.below(n);
                        let layer = g.rng.below(cfg.n_layers);
                        let head = g.rng.below(cfg.n_heads);
                        let src = (layer * cfg.n_heads + head) * dh;
                        let dst = ((layer * cfg.n_heads + head) * t_max + t) * dh;
                        let truth = &want[t].0[src..src + dh];
                        let got = &k_out[dst..dst + dh];
                        let rel = isoquant::metrics::rel_l2(truth, got);
                        if rel > 0.5 {
                            return Err(format!("reconstruction rel err {rel}"));
                        }
                    }
                }
                _ => {}
            }
        }
        if mgr.active_seqs() != live.len() {
            return Err("sequence accounting mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_f16_path_tracks_f32_path() {
    use isoquant::util::f16;
    check(60, 0xF16, |g| {
        let variant = *g.choose(&[Variant::IsoFull, Variant::IsoFast, Variant::Planar2D]);
        let d = (g.usize_in(1, 64) * 4).max(4);
        let bits = g.usize_in(2, 4) as u8;
        let x = g.vec_f32(d, 1.0);
        let s = Stage1::new(Stage1Config::new(variant, d, bits));
        let mut out32 = vec![0.0f32; d];
        s.roundtrip(&x, &mut out32);
        let xh: Vec<u16> = x.iter().map(|&v| f16::f32_to_f16_bits(v)).collect();
        let mut out16 = vec![0u16; d];
        s.roundtrip_batch_f16(&xh, &mut out16, 1);
        let out16f: Vec<f32> = out16.iter().map(|&h| f16::f16_bits_to_f32(h)).collect();
        let diff = mse(&out32, &out16f);
        if diff > 1e-3 {
            return Err(format!("{variant:?} d={d} b={bits}: f16 drift {diff}"));
        }
        Ok(())
    });
}

#[test]
fn prop_double_cover_through_full_pipeline() {
    // negating both quaternion banks leaves the whole stage-1 pipeline
    // invariant (paper Prop. 1 eq. 13), not just the raw sandwich
    check(60, 0xD0B1E, |g| {
        let d = (g.usize_in(1, 32) * 4).max(4);
        let bits = g.usize_in(2, 4) as u8;
        let cfg = Stage1Config::new(Variant::IsoFull, d, bits);
        let bank = ParamBank::random(Variant::IsoFull, d, g.rng.next_u64());
        let mut neg = bank.clone();
        for q in neg.q_l.iter_mut().chain(neg.q_r.iter_mut()) {
            *q = [-q[0], -q[1], -q[2], -q[3]];
        }
        let s1 = Stage1::with_bank(cfg.clone(), bank);
        let s2 = Stage1::with_bank(cfg, neg);
        let x = g.vec_f32(d, 1.0);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        s1.roundtrip(&x, &mut a);
        s2.roundtrip(&x, &mut b);
        assert_close(&a, &b, 1e-6, 1e-6)
    });
}

#[test]
fn prop_batcher_never_loses_or_duplicates() {
    use isoquant::coordinator::{Batcher, Request};
    use std::time::{Duration, Instant};
    check(80, 0xBA7C4, |g| {
        let max_batch = g.usize_in(1, 8);
        let window_us = g.usize_in(0, 5000) as u64;
        let mut b = Batcher::new(Duration::from_micros(window_us), max_batch);
        let t0 = Instant::now();
        let n = g.usize_in(0, 50);
        for i in 0..n {
            b.submit_at(
                Request::new(i as u64, vec![1], 1),
                t0,
            );
        }
        let mut seen = Vec::new();
        let mut now = t0;
        loop {
            now += Duration::from_micros(window_us + 1);
            match b.poll(now) {
                Some(batch) => {
                    if batch.len() > max_batch {
                        return Err(format!("batch size {} > {max_batch}", batch.len()));
                    }
                    seen.extend(batch.iter().map(|r| r.id));
                }
                None => break,
            }
        }
        if seen.len() != n {
            return Err(format!("saw {} of {n} requests", seen.len()));
        }
        let sorted: Vec<u64> = (0..n as u64).collect();
        if seen != sorted {
            return Err("order or duplication violation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_stage1_deterministic() {
    // same config + seed + input → bit-identical output (required for
    // the KV cache: decode must reproduce encode-time reconstructions)
    check(40, 0xDE7, |g| {
        let variant = *g.choose(&VARIANTS);
        let d = if variant == Variant::Dense { 32 } else { 128 };
        let bits = g.usize_in(2, 4) as u8;
        let seed = g.rng.next_u64();
        let mut cfg = Stage1Config::new(variant, d, bits);
        cfg.seed = seed;
        let s1 = Stage1::new(cfg.clone());
        let s2 = Stage1::new(cfg);
        let x = g.vec_f32(d, 1.0);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        s1.roundtrip(&x, &mut a);
        s2.roundtrip(&x, &mut b);
        if a != b {
            return Err("non-deterministic pipeline".into());
        }
        Ok(())
    });
}

#[test]
fn prop_learned_rotations_never_worse_on_train() {
    use isoquant::quant::learn::{learn, LearnOptions};
    check(8, 0x1EA2, |g| {
        let d = 16;
        let n = 64;
        let mut rng = Rng::new(g.rng.next_u64());
        let data: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let variant = *g.choose(&[Variant::IsoFull, Variant::IsoFast, Variant::Planar2D]);
        let cfg = Stage1Config::new(variant, d, 2);
        let (_s, before, after) = learn(
            cfg,
            &data,
            n,
            &LearnOptions {
                iters: 10,
                seed: g.rng.next_u64(),
                ..Default::default()
            },
        );
        // per-block accept-only-if-better ⇒ monotone non-increasing
        if after > before * (1.0 + 1e-9) {
            return Err(format!("train MSE increased {before} → {after}"));
        }
        Ok(())
    });
}
