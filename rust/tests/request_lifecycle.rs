//! Request-lifecycle hardening tests: cancellation, deadlines,
//! backpressure shedding, graceful drain, and the store fault-injection
//! sweep.
//!
//! Two tiers:
//! * store/manager-level tests run everywhere (no artifacts needed) —
//!   the fault-injection contract is **degrade, never crash; miss,
//!   never wrong bytes**;
//! * engine/server-level tests need `make artifacts` and SKIP (pass
//!   trivially, with a note) when artifacts are absent, exactly like
//!   the other integration suites.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, FinishReason, Request};
use isoquant::kvcache::store::segment_path;
use isoquant::kvcache::{
    chain_key, CacheManager, FaultPlan, FaultyIo, PageConfig, PageStore, PrefixKey, StoreConfig,
};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::runtime::ServingModel;
use isoquant::server::{serve_on, Client};
use isoquant::util::json::Json;
use isoquant::util::prng::Rng;

// ---------------------------------------------------------------------
// store-level fault injection (no artifacts needed)
// ---------------------------------------------------------------------

fn tmpdir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "isoquant-lifecycle-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Store config for fault tests: buffered reads (the injector shims the
/// buffered transport; mmap'd views are plain memory), zero backoff so
/// retries replay instantly.
fn fault_cfg(dir: &Path, retries: u32, degrade_after: u32) -> StoreConfig {
    let mut c = StoreConfig::for_cache(dir.to_path_buf(), 7, 64, 0)
        .with_mmap(false)
        .with_fault_policy(retries, 0, degrade_after);
    c.segment_bytes = 1 << 20;
    c
}

fn key(i: u64) -> PrefixKey {
    chain_key(None, &[i as i32], 0xF00D)
}

#[test]
fn write_failure_retries_on_fresh_segment_and_succeeds() {
    let dir = tmpdir("retry-write");
    let io = FaultyIo::new(FaultPlan {
        fail_writes: vec![0], // first record write fails, retry must land
        ..FaultPlan::default()
    });
    let store = PageStore::open_with_io(fault_cfg(&dir, 2, 100), io).unwrap();
    assert!(store.spill(key(1), None, &[1], &vec![0xA5u8; 64]));
    store.flush();
    let stats = store.stats();
    assert_eq!(stats.spilled, 1, "the retry must succeed");
    assert!(stats.spill_retries >= 1, "a retry must be counted");
    assert_eq!(stats.spill_errors, 0);
    assert!(!store.degraded());
    assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0xA5u8; 64]));
    // the torn first attempt landed nothing: its abandoned segment must
    // not linger as an empty file
    assert!(!segment_path(&dir, 0).exists(), "empty failed segment must be unlinked");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn create_failure_retries_with_a_fresh_segment_id() {
    let dir = tmpdir("retry-create");
    let io = FaultyIo::new(FaultPlan {
        fail_creates: vec![0], // ENOSPC creating the first segment
        ..FaultPlan::default()
    });
    let store = PageStore::open_with_io(fault_cfg(&dir, 1, 100), io).unwrap();
    assert!(store.spill(key(1), None, &[1], &vec![0x11u8; 64]));
    store.flush();
    assert_eq!(store.stats().spilled, 1);
    assert!(!store.degraded());
    assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0x11u8; 64]));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn consecutive_failures_degrade_to_disabled_without_crashing() {
    let dir = tmpdir("degrade");
    let store =
        PageStore::open_with_io(fault_cfg(&dir, 0, 2), FaultyIo::new(FaultPlan::all_writes_fail()))
            .unwrap();
    for i in 0..3u64 {
        store.spill(key(i), None, &[i as i32], &vec![i as u8; 64]);
    }
    store.flush();
    assert!(store.degraded(), "2 consecutive failures must trip degrade");
    assert_eq!(store.len(), 0, "nothing became durable");
    assert!(store.stats().spill_errors >= 2);
    // degraded: new spills are refused at the door, loudly countable
    assert!(!store.spill(key(9), None, &[9], &vec![9u8; 64]));
    store.flush(); // still answers — the worker drains, it doesn't wedge
    drop(store); // clean shutdown, no panic
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn degraded_store_keeps_serving_already_durable_reads() {
    let dir = tmpdir("degrade-reads");
    let io = FaultyIo::new(FaultPlan {
        fail_writes: (1..50).collect(), // first write lands, the rest fail
        ..FaultPlan::default()
    });
    let store = PageStore::open_with_io(fault_cfg(&dir, 0, 1), io).unwrap();
    assert!(store.spill(key(1), None, &[1], &vec![0xEEu8; 64]));
    store.flush();
    assert!(!store.degraded());
    store.spill(key(2), None, &[2], &vec![0x22u8; 64]);
    store.flush();
    assert!(store.degraded(), "one exhausted job with degrade_after=1");
    // what was durable before the disk died keeps serving
    assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0xEEu8; 64]));
    assert_eq!(store.len(), 1);
    assert!(!store.spill(key(3), None, &[3], &vec![3u8; 64]));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn short_write_leaves_a_torn_tail_that_scans_clean_on_reopen() {
    let dir = tmpdir("torn");
    let io = FaultyIo::new(FaultPlan {
        short_writes: vec![1], // second record lands half, then ENOSPC
        ..FaultPlan::default()
    });
    {
        let store = PageStore::open_with_io(fault_cfg(&dir, 0, 100), io).unwrap();
        assert!(store.spill(key(1), None, &[1], &vec![0x11u8; 64]));
        store.flush();
        store.spill(key(2), None, &[2], &vec![0x22u8; 64]); // torn
        store.flush();
        assert_eq!(store.stats().spill_errors, 1);
        // the worker abandoned the torn segment; the next spill goes to
        // a fresh one and must succeed
        assert!(store.spill(key(3), None, &[3], &vec![0x33u8; 64]));
        store.flush();
        assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0x11u8; 64]));
        assert!(store.read_page(key(2), None, &[2]).is_none(), "torn record is a miss");
        assert_eq!(store.read_page(key(3), None, &[3]), Some(vec![0x33u8; 64]));
    }
    // reopen with a healthy disk: the torn tail terminates one
    // segment's scan; every intact record survives
    let store = PageStore::open(fault_cfg(&dir, 0, 100)).unwrap();
    assert_eq!(store.len(), 2, "k1 + k3 rehydrate, torn k2 does not");
    assert_eq!(store.stats().corrupt_tails, 1);
    assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0x11u8; 64]));
    assert_eq!(store.read_page(key(3), None, &[3]), Some(vec![0x33u8; 64]));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn injected_read_errors_read_as_miss_never_wrong_bytes() {
    // open-failure and read-failure injection: the damaged read is a
    // dropped-entry miss; the next key still serves its exact bytes
    for (plan, tag) in [
        (FaultPlan { fail_opens: vec![0], ..FaultPlan::default() }, "open"),
        (FaultPlan { fail_reads: vec![0], ..FaultPlan::default() }, "read"),
    ] {
        let dir = tmpdir(&format!("read-miss-{tag}"));
        let store = PageStore::open_with_io(fault_cfg(&dir, 0, 100), FaultyIo::new(plan)).unwrap();
        assert!(store.spill(key(1), None, &[1], &vec![0x44u8; 64]));
        assert!(store.spill(key(2), None, &[2], &vec![0x55u8; 64]));
        store.flush();
        assert!(
            store.read_page(key(1), None, &[1]).is_none(),
            "{tag}: injected failure must be a miss"
        );
        assert_eq!(store.stats().read_errors, 1, "{tag}");
        assert_eq!(store.len(), 1, "{tag}: failed entry dropped, not retried forever");
        assert_eq!(
            store.read_page(key(2), None, &[2]),
            Some(vec![0x55u8; 64]),
            "{tag}: healthy reads keep serving exact bytes"
        );
        let _ = fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------
// manager-level cancellation (no artifacts needed)
// ---------------------------------------------------------------------

const TP: usize = 4;
const D_HEAD: usize = 32;

fn mk_cache(max_pages: usize) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, D_HEAD, 3));
    let cfg = PageConfig {
        tokens_per_page: TP,
        n_layers: 2,
        n_heads: 2,
        d_head: D_HEAD,
        encoded_len: stage1.encoded_len(),
    };
    let mut m = CacheManager::new(stage1, cfg, max_pages);
    m.prefix_sharing = true;
    m
}

fn kv_at(stream: &[i32], t: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let seed = chain_key(None, &stream[..=t], 0xBEEF).0;
    let mut rng = Rng::new(seed);
    let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
    (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
}

fn append_stream(m: &mut CacheManager, seq: u64, stream: &[i32], from: usize) {
    let cfg = m.page_cfg();
    for t in from..stream.len() {
        let (k, v) = kv_at(stream, t, &cfg);
        m.append_token(seq, &k, &v).unwrap();
    }
}

fn gather_bits(m: &CacheManager, seq: u64, t_max: usize) -> Vec<u32> {
    let cfg = m.page_cfg();
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut k, mut v) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    m.gather(seq, t_max, &mut k, &mut v).unwrap();
    k.iter().chain(v.iter()).map(|x| x.to_bits()).collect()
}

#[test]
fn cancelling_one_shared_prefix_lane_leaves_the_survivor_byte_identical() {
    // two lanes share a prompt; the engine's cancel path is
    // `drop_seq(seq)` — dropping one mid-decode must free its pages
    // (refcounts to zero) without disturbing the survivor's bytes
    let mut m = mk_cache(64);
    let prompt: Vec<i32> = (0..10).collect();
    m.start_seq_with_prompt(1, &prompt).unwrap();
    append_stream(&mut m, 1, &prompt, 0);
    let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
    assert!(reuse.pages > 0, "second lane must adopt the shared prefix");
    // both lanes decode divergently
    let mut s1 = prompt.clone();
    let mut s2 = prompt.clone();
    for d in 0..6 {
        s1.push(1_000 + d);
        s2.push(2_000 + d);
    }
    append_stream(&mut m, 1, &s1, prompt.len());
    append_stream(&mut m, 2, &s2, prompt.len());
    let survivor_before = gather_bits(&m, 2, s2.len());
    let pages_before = m.live_pages();

    m.drop_seq(1); // the cancel
    assert!(m.live_pages() < pages_before, "cancel must return pages");
    assert_eq!(
        gather_bits(&m, 2, s2.len()),
        survivor_before,
        "cancelling a sibling must not change the survivor's bytes"
    );
    m.drop_seq(2);
    assert_eq!(m.live_refs(), 0, "all refcounts return to zero");
}

// ---------------------------------------------------------------------
// engine/server-level lifecycle (needs artifacts; skips cleanly)
// ---------------------------------------------------------------------

/// The XLA CPU runtime does not tolerate concurrent PJRT client
/// creation in one process; serialize everything that touches PJRT.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = isoquant::runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts not built; skipping lifecycle integration test");
        None
    }
}

fn mk_engine(dir: &Path, cfg: EngineConfig) -> Engine {
    let model = ServingModel::load(dir).expect("load model");
    Engine::new(model, cfg).expect("boot engine")
}

#[test]
fn cancel_mid_decode_frees_lane_and_pages_within_one_step() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = mk_engine(&dir, EngineConfig::default());
    engine.submit(Request::new(1, vec![3, 1, 4, 1, 5], 64));
    // admit + prefill + at least one decode step
    for _ in 0..4 {
        engine.step().unwrap();
    }
    assert_eq!(engine.active(), 1, "request must be mid-flight");
    assert!(engine.take_completions().is_empty());

    assert!(engine.cancel(1), "known in-flight id");
    assert_eq!(engine.active(), 0, "lane freed immediately");
    assert_eq!(engine.cache.live_refs(), 0, "pages returned within one step");
    assert!(engine.take_completions().is_empty(), "no completion for a dead socket");
    assert_eq!(engine.cache.share.requests_cancelled, 1);
    assert!(!engine.cancel(1), "second cancel of the same id is a no-op");

    // the pool is fully usable afterwards
    engine.submit(Request::new(2, vec![2, 7, 1, 8], 4));
    let comps = engine.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].finish, FinishReason::MaxTokens);
}

#[test]
fn cancel_while_queued_drops_the_request_silently() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = mk_engine(&dir, EngineConfig::default());
    engine.submit(Request::new(1, vec![1, 2, 3], 4));
    assert_eq!(engine.pending(), 1);
    assert!(engine.cancel(1));
    assert_eq!(engine.pending(), 0);
    assert!(engine.run_to_completion().unwrap().is_empty());
    assert_eq!(engine.cache.share.requests_cancelled, 1);
}

#[test]
fn deadline_expires_before_first_token_and_mid_decode() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    // (a) queued/prefill expiry: a 1 ms deadline dies before any token
    let mut cfg = EngineConfig::default();
    cfg.request_timeout_ms = 1;
    let mut engine = mk_engine(&dir, cfg);
    engine.submit(Request::new(1, vec![1; 32], 8));
    std::thread::sleep(std::time::Duration::from_millis(5));
    let comps = engine.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].finish, FinishReason::Timeout);
    assert!(comps[0].tokens.is_empty(), "expired before generating anything");
    assert_eq!(engine.cache.share.requests_timed_out, 1);

    // (b) mid-decode expiry: a generous deadline lets decode start,
    // then expires long before 200 tokens could complete — the partial
    // output comes back with finish=timeout
    let mut engine = mk_engine(&dir, EngineConfig::default());
    let mut req = Request::new(2, vec![2, 7, 1, 8], 200);
    req.deadline_ms = Some(40); // per-request deadline, no server default
    engine.submit(req);
    let comps = engine.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].finish, FinishReason::Timeout);
    assert!(
        comps[0].tokens.len() < 200,
        "deadline must interrupt decode, got all {} tokens",
        comps[0].tokens.len()
    );
    assert_eq!(engine.cache.share.requests_timed_out, 1);
    assert_eq!(engine.cache.live_refs(), 0, "timeout frees the lane's pages");
}

#[test]
fn shed_waiting_rejects_every_queued_request() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = mk_engine(&dir, EngineConfig::default());
    engine.submit(Request::new(1, vec![1, 2], 4));
    engine.submit(Request::new(2, vec![3, 4], 4));
    assert_eq!(engine.shed_waiting(), 2);
    let comps = engine.take_completions();
    assert_eq!(comps.len(), 2);
    assert!(comps.iter().all(|c| c.finish == FinishReason::Rejected));
    assert_eq!(engine.cache.share.requests_shed, 2);
}

// -------------------------- TCP server ------------------------------

struct ServeHandle {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<isoquant::server::ServeReport>,
}

fn boot_server(dir: &Path, mut mutate: impl FnMut(&mut EngineConfig)) -> ServeHandle {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let dir = dir.to_path_buf();
    let mut cfg = EngineConfig::default();
    mutate(&mut cfg);
    let thread = std::thread::spawn(move || {
        let model = ServingModel::load(&dir).expect("load model");
        let engine = Engine::new(model, cfg).expect("boot engine");
        serve_on(engine, listener, stop_srv).expect("serve")
    });
    ServeHandle { addr, stop, thread }
}

impl ServeHandle {
    fn shutdown(self) -> isoquant::server::ServeReport {
        self.stop.store(true, Ordering::SeqCst);
        self.thread.join().unwrap()
    }
}

#[test]
fn server_disconnect_mid_decode_cancels_and_frees_the_lane() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        // fire a long decode, then vanish without reading the response
        let mut c = Client::connect(&srv.addr).expect("connect");
        c.send(1, &[5, 3, 1], 200, None).expect("send");
        std::thread::sleep(std::time::Duration::from_millis(150));
    } // drop = socket close = EOF at the reader
    // give the reader + serve loop time to route the cancel
    std::thread::sleep(std::time::Duration::from_millis(300));
    let report = srv.shutdown();
    assert_eq!(report.share.requests_cancelled, 1, "disconnect must cancel");
    assert_eq!(report.undrained_lanes, 0, "cancelled lane must not need draining");
    assert_eq!(report.share.requests_timed_out, 0);
}

#[test]
fn server_sheds_overload_with_a_structured_error() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let lanes = isoquant::runtime::Manifest::load(&dir)
        .expect("manifest")
        .model
        .serve_batch;
    let srv = boot_server(&dir, |cfg| cfg.max_queue = 1);
    let n_clients = lanes + 4;
    let results: Vec<_> = (0..n_clients)
        .map(|i| {
            let addr = srv.addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                c.send(i as u64 + 1, &[7, 2, 9], 32, None).expect("send");
                let v = c.recv().expect("recv");
                match v.get("error").and_then(|e| e.as_str()) {
                    Some(e) => {
                        assert_eq!(e, "overloaded");
                        assert!(v.get("retry_after_ms").and_then(|r| r.as_usize()).is_some());
                        true // shed
                    }
                    None => {
                        assert!(v.get("tokens").is_some(), "non-shed requests complete: {v:?}");
                        false
                    }
                }
            })
        })
        .collect();
    let shed = results
        .into_iter()
        .map(|j| j.join().unwrap())
        .filter(|&s| s)
        .count();
    let report = srv.shutdown();
    assert!(
        shed >= 1,
        "{n_clients} bursty clients against max_queue=1 must shed at least one"
    );
    assert_eq!(report.share.requests_shed as usize, shed, "counter matches responses");
    assert_eq!(report.share.requests_cancelled, 0);
}

#[test]
fn server_request_deadline_times_out_over_tcp() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    let mut c = Client::connect(&srv.addr).expect("connect");
    c.send(1, &[4, 4, 4], 200, Some(40)).expect("send");
    let v = c.recv().expect("recv");
    assert_eq!(v.get("finish").and_then(|f| f.as_str()), Some("timeout"));
    let n_tokens = v.get("tokens").unwrap().as_arr().unwrap().len();
    assert!(n_tokens < 200, "partial output, not a full decode");
    drop(c);
    let report = srv.shutdown();
    assert_eq!(report.share.requests_timed_out, 1);
}

#[test]
fn malformed_requests_get_structured_errors_not_a_dead_connection() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        use std::io::{BufRead, BufReader, Write};
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        // negative token: rejected with an error line, connection stays up
        writeln!(s, r#"{{"id": 1, "prompt": [1, -2]}}"#).unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "got: {line}");
        // the same connection can then serve a valid request
        writeln!(s, r#"{{"id": 2, "prompt": [1, 2], "max_new_tokens": 4}}"#).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains(r#""finish": "max_tokens""#), "got: {line}");
    }
    std::thread::sleep(std::time::Duration::from_millis(100));
    let report = srv.shutdown();
    assert_eq!(report.requests, 1, "only the valid request reached the engine");
    assert_eq!(report.share.requests_cancelled, 0, "finished ids cancel as no-ops");
}

/// Graceful drain under load: stop the server while a decode is still
/// running — the in-flight request must finish (not be dropped), its
/// completion delivered, and the drain must leave no lane behind.
#[test]
fn graceful_drain_finishes_in_flight_requests() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |cfg| cfg.drain_timeout_ms = 30_000);
    let mut c = Client::connect(&srv.addr).expect("connect");
    c.send(1, &[6, 1, 6], 48, None).expect("send");
    std::thread::sleep(std::time::Duration::from_millis(50));
    // stop while (very likely) mid-decode; the drain must still deliver
    srv.stop.store(true, Ordering::SeqCst);
    let v = c.recv().expect("drain must deliver the completion");
    assert_eq!(v.get("finish").and_then(|f| f.as_str()), Some("max_tokens"));
    assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 48);
    let report = srv.thread.join().unwrap();
    assert_eq!(report.undrained_lanes, 0, "drain must complete");
    assert_eq!(report.share.requests_cancelled, 0);
}

// ---------------- streaming + reactor front end ---------------------

fn send_raw(s: &mut std::net::TcpStream, line: &str) {
    use std::io::Write;
    writeln!(s, "{line}").expect("send");
}

fn stream_req(id: u64, prompt: &[i32], max_new: usize, deadline_ms: Option<u64>) -> String {
    let mut line = format!(
        r#"{{"id": {id}, "prompt": {prompt:?}, "max_new_tokens": {max_new}, "stream": true"#
    );
    if let Some(ms) = deadline_ms {
        line.push_str(&format!(r#", "deadline_ms": {ms}"#));
    }
    line.push('}');
    line
}

/// Read response lines until the terminal one (a completion or an
/// error); returns the token lines seen on the way plus the terminal.
fn read_stream(r: &mut impl std::io::BufRead) -> (Vec<Json>, Json) {
    let mut toks = Vec::new();
    loop {
        let mut line = String::new();
        let n = r.read_line(&mut line).expect("read");
        assert!(n > 0, "connection closed before a terminal line");
        let v = Json::parse(line.trim()).expect("valid JSON line");
        if v.get("finish").is_some() || v.get("error").is_some() {
            return (toks, v);
        }
        toks.push(v);
    }
}

#[test]
fn streaming_delivers_every_token_then_the_terminal_line() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        let mut r = std::io::BufReader::new(s.try_clone().unwrap());
        send_raw(&mut s, &stream_req(7, &[3, 1, 4], 6, None));
        let (toks, term) = read_stream(&mut r);
        assert_eq!(term.get("finish").and_then(|f| f.as_str()), Some("max_tokens"));
        let final_tokens: Vec<i64> = term
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as i64)
            .collect();
        assert_eq!(final_tokens.len(), 6);
        assert_eq!(toks.len(), 6, "one streamed line per generated token");
        for (i, t) in toks.iter().enumerate() {
            assert_eq!(t.get("id").and_then(|x| x.as_usize()), Some(7));
            assert_eq!(t.get("index").and_then(|x| x.as_usize()), Some(i), "ascending index");
            assert_eq!(
                t.get("token").and_then(|x| x.as_f64()).map(|x| x as i64),
                Some(final_tokens[i]),
                "streamed token matches the terminal transcript"
            );
        }
    } // clean close after a delivered terminal: nothing left to cancel
    std::thread::sleep(std::time::Duration::from_millis(200));
    let report = srv.shutdown();
    assert_eq!(report.requests, 1);
    assert_eq!(report.share.requests_cancelled, 0, "finished ids cancel as no-ops");
}

#[test]
fn streaming_disconnect_mid_stream_cancels() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        let mut r = std::io::BufReader::new(s.try_clone().unwrap());
        send_raw(&mut s, &stream_req(1, &[5, 3, 1], 200, None));
        // wait for proof the stream is live, then vanish mid-decode
        let mut line = String::new();
        use std::io::BufRead;
        r.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert!(v.get("token").is_some(), "expected a token line, got: {line}");
    } // drop = socket close mid-stream
    std::thread::sleep(std::time::Duration::from_millis(300));
    let report = srv.shutdown();
    assert_eq!(report.share.requests_cancelled, 1, "mid-stream disconnect must cancel");
    assert_eq!(report.undrained_lanes, 0, "cancelled lane must not need draining");
}

#[test]
fn streaming_deadline_returns_partial_tokens_then_timeout() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        let mut r = std::io::BufReader::new(s.try_clone().unwrap());
        send_raw(&mut s, &stream_req(9, &[4, 4, 4], 200, Some(40)));
        let (toks, term) = read_stream(&mut r);
        assert_eq!(term.get("finish").and_then(|f| f.as_str()), Some("timeout"));
        let n = term.get("tokens").unwrap().as_arr().unwrap().len();
        assert!(n < 200, "deadline must interrupt decode, got all {n} tokens");
        assert_eq!(toks.len(), n, "every generated token streamed before the timeout line");
    }
    std::thread::sleep(std::time::Duration::from_millis(200));
    let report = srv.shutdown();
    assert_eq!(report.share.requests_timed_out, 1);
    assert_eq!(report.share.requests_cancelled, 0);
}

#[test]
fn streaming_malformed_then_valid_on_one_connection() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    {
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        let mut r = std::io::BufReader::new(s.try_clone().unwrap());
        // non-boolean stream flag: structured error, connection stays up
        send_raw(&mut s, r#"{"id": 1, "prompt": [1, 2], "stream": "yes"}"#);
        let (toks, err) = read_stream(&mut r);
        assert!(toks.is_empty());
        let msg = err.get("error").and_then(|e| e.as_str()).expect("error line");
        assert!(msg.contains("stream"), "got: {msg}");
        // the same connection then streams a valid request
        send_raw(&mut s, &stream_req(2, &[1, 2], 4, None));
        let (toks, term) = read_stream(&mut r);
        assert_eq!(term.get("finish").and_then(|f| f.as_str()), Some("max_tokens"));
        assert_eq!(toks.len(), 4);
    }
    std::thread::sleep(std::time::Duration::from_millis(200));
    let report = srv.shutdown();
    assert_eq!(report.requests, 1, "only the valid request reached the engine");
}

/// Graceful drain with a stream in flight: every remaining token line
/// and the terminal completion must still be delivered.
#[test]
fn graceful_drain_mid_stream_delivers_every_token() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |cfg| cfg.drain_timeout_ms = 30_000);
    let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
    let mut r = std::io::BufReader::new(s.try_clone().unwrap());
    send_raw(&mut s, &stream_req(3, &[6, 1, 6], 48, None));
    std::thread::sleep(std::time::Duration::from_millis(50));
    // stop while (very likely) mid-stream; the drain must still deliver
    srv.stop.store(true, Ordering::SeqCst);
    let (toks, term) = read_stream(&mut r);
    assert_eq!(term.get("finish").and_then(|f| f.as_str()), Some("max_tokens"));
    assert_eq!(term.get("tokens").unwrap().as_arr().unwrap().len(), 48);
    assert_eq!(toks.len(), 48, "no token line lost across the drain");
    let report = srv.thread.join().unwrap();
    assert_eq!(report.undrained_lanes, 0, "drain must complete");
    assert_eq!(report.share.requests_cancelled, 0);
}

#[test]
fn stats_request_reports_share_counters_and_latency() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |_| {});
    let mut c = Client::connect(&srv.addr).expect("connect");
    c.send(1, &[2, 2], 4, None).expect("send");
    let done = c.recv().expect("completion");
    assert!(done.get("finish").is_some());
    c.send_line(r#"{"stats": true}"#).expect("stats request");
    let v = c.recv().expect("stats reply");
    assert_eq!(v.get("stats").and_then(|s| s.as_bool()), Some(true));
    assert!(v.get("share").is_some(), "share section: {v:?}");
    assert!(v.get("pages").is_some(), "pages section: {v:?}");
    let counters = v.get("counters").expect("counters section");
    assert_eq!(counters.get("requests").and_then(|r| r.as_usize()), Some(1));
    let ttft = v.get("latency").expect("latency section").get("ttft_us").expect("ttft");
    assert_eq!(ttft.get("n").and_then(|n| n.as_usize()), Some(1));
    assert!(ttft.get("p50_us").and_then(|p| p.as_f64()).unwrap() > 0.0);
    drop(c);
    std::thread::sleep(std::time::Duration::from_millis(200));
    let report = srv.shutdown();
    assert_eq!(report.requests, 1, "the stats request never reaches the engine's request path");
}

#[test]
fn oversized_request_line_disconnects_and_counts_overflow() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = boot_server(&dir, |cfg| cfg.max_conn_buffer_kb = 1);
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&srv.addr).unwrap();
        // 4 KiB with no terminating newline: the reactor must cut the
        // connection at the 1 KiB cap instead of buffering forever
        s.write_all(&[b'x'; 4096]).unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "server must close the connection, not reply");
    }
    let report = srv.shutdown();
    assert_eq!(report.conn_overflow_disconnects, 1);
    assert_eq!(report.share.requests_cancelled, 0, "nothing was submitted to cancel");
}

#[cfg(target_os = "linux")]
fn raise_fd_limit() {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur < r.max {
            let want = RLimit { cur: r.max, max: r.max };
            let _ = setrlimit(RLIMIT_NOFILE, &want);
        }
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_fd_limit() {}

/// Connections this one process can afford: each costs two fds (client
/// end + server end), with slack for PJRT, the store, and the harness.
fn fd_budget_conns() -> usize {
    #[cfg(target_os = "linux")]
    {
        #[repr(C)]
        struct RLimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        }
        unsafe {
            let mut r = RLimit { cur: 0, max: 0 };
            if getrlimit(7, &mut r) == 0 {
                return ((r.cur.saturating_sub(128) / 2) as usize).max(64);
            }
        }
        512
    }
    #[cfg(not(target_os = "linux"))]
    {
        512
    }
}

/// Concurrency smoke: hundreds of simultaneous connections through one
/// reactor, every client getting a definitive outcome (completion or
/// structured shed) and the lifecycle counters summing to the request
/// count.
#[test]
fn many_concurrent_connections_get_definitive_outcomes() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    raise_fd_limit();
    let n = 512usize.min(fd_budget_conns());
    let srv = boot_server(&dir, |_| {});
    let ok = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let addr = srv.addr.clone();
        let ok = ok.clone();
        let shed = shed.clone();
        let h = std::thread::Builder::new()
            .stack_size(128 * 1024)
            .spawn(move || {
                // a thundering herd can outrun the accept backlog:
                // retry briefly instead of failing the connect
                let mut c = None;
                for _ in 0..100 {
                    match Client::connect(&addr) {
                        Ok(x) => {
                            c = Some(x);
                            break;
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                    }
                }
                let mut c = c.expect("connect after retries");
                c.send(i as u64 + 1, &[9, 9], 2, None).expect("send");
                let v = c.recv().expect("recv");
                if v.get("finish").is_some() {
                    ok.fetch_add(1, Ordering::Relaxed);
                } else if v.get("error").is_some() {
                    shed.fetch_add(1, Ordering::Relaxed);
                } else {
                    panic!("non-definitive response line: {v:?}");
                }
            })
            .expect("spawn client");
        handles.push(h);
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let (ok, shed) = (ok.load(Ordering::Relaxed), shed.load(Ordering::Relaxed));
    assert_eq!(ok + shed, n as u64, "every connection got a definitive outcome");
    std::thread::sleep(std::time::Duration::from_millis(200));
    let report = srv.shutdown();
    assert_eq!(report.requests, ok, "engine saw exactly the admitted requests");
    assert_eq!(report.share.requests_shed, shed, "shed counter matches shed responses");
    assert_eq!(report.share.requests_cancelled, 0, "no client vanished: nothing to cancel");
    assert_eq!(report.undrained_lanes, 0);
}
