//! Prefix-sharing property tests: any interleaving of
//! {admit-with-shared-prefix, CoW append, drop_seq} must yield gathers
//! byte-identical to an unshared reference cache, and every page
//! ownership must return to zero once all sequences drop.
//!
//! The "model" here is a deterministic map from a token-id prefix to
//! K/V vectors (same prefix ⇒ same vectors), which is exactly the
//! property that makes real prompt prefixes shareable.

use isoquant::kvcache::{chain_key, CacheManager, GatherWorkspace, PageConfig, PageStore, StoreConfig};
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::util::pool::ParallelPolicy;
use isoquant::util::prng::Rng;
use isoquant::util::proplite::{check, Gen};

struct Geometry {
    cfg: PageConfig,
    bits: u8,
}

fn geometry(g: &mut Gen) -> Geometry {
    let dh = 4 * g.usize_in(4, 12); // 16..48, multiple of 4
    let bits = g.usize_in(2, 4) as u8;
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
    Geometry {
        cfg: PageConfig {
            tokens_per_page: g.usize_in(2, 5),
            n_layers: g.usize_in(1, 2),
            n_heads: g.usize_in(1, 2),
            d_head: dh,
            encoded_len: stage1.encoded_len(),
        },
        bits,
    }
}

fn mk_cache(geo: &Geometry, max_pages: usize, sharing: bool) -> CacheManager {
    let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, geo.cfg.d_head, geo.bits));
    let mut m = CacheManager::new(stage1, geo.cfg, max_pages);
    m.prefix_sharing = sharing;
    m
}

/// Deterministic K/V for the token at position `t` of `stream`: seeded
/// by the chained hash of `stream[..=t]`, so equal prefixes produce
/// equal vectors — the stand-in for a real model's prefix-determined
/// K/V.
fn kv_at(stream: &[i32], t: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let seed = chain_key(None, &stream[..=t], 0xBEEF).0;
    let mut rng = Rng::new(seed);
    let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
    (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
}

/// Flatten tokens `from..to` of `stream` into one token-major run.
fn kv_run(stream: &[i32], from: usize, to: usize, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::new();
    let mut v = Vec::new();
    for t in from..to {
        let (tk, tv) = kv_at(stream, t, cfg);
        k.extend_from_slice(&tk);
        v.extend_from_slice(&tv);
    }
    (k, v)
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Gather `seq` from both caches through every path and demand
/// bit-identical results everywhere.
fn verify_seq(
    shared: &CacheManager,
    unshared: &CacheManager,
    seq: u64,
    len: usize,
    cfg: &PageConfig,
    ws: &mut GatherWorkspace,
) -> Result<(), String> {
    let t_max = len.max(1) + 2;
    let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
    let (mut ks, mut vs) = (vec![0.0f32; sz], vec![0.0f32; sz]);
    let (mut ko, mut vo) = (vec![1.0f32; sz], vec![1.0f32; sz]);
    let (mut kr, mut vr) = (vec![2.0f32; sz], vec![2.0f32; sz]);
    let n1 = shared
        .gather_ws(seq, t_max, &mut ks, &mut vs, ws)
        .map_err(|e| e.to_string())?;
    let n2 = shared
        .gather_reference(seq, t_max, &mut ko, &mut vo)
        .map_err(|e| e.to_string())?;
    let n3 = unshared
        .gather_reference(seq, t_max, &mut kr, &mut vr)
        .map_err(|e| e.to_string())?;
    if n1 != len || n2 != len || n3 != len {
        return Err(format!("seq {seq}: lengths {n1}/{n2}/{n3} != {len}"));
    }
    if bits_of(&ks) != bits_of(&ko) || bits_of(&vs) != bits_of(&vo) {
        return Err(format!("seq {seq}: batched gather != reference on shared cache"));
    }
    if bits_of(&ks) != bits_of(&kr) || bits_of(&vs) != bits_of(&vr) {
        return Err(format!("seq {seq}: shared cache != unshared cache"));
    }
    Ok(())
}

#[test]
fn prop_shared_cache_bit_identical_to_unshared() {
    check(20, 0x5A4E, |g| {
        let geo = geometry(g);
        let cfg = geo.cfg;
        // shared cache under (possible) pool pressure; reference cache
        // never shares and never evicts
        let pool = g.usize_in(24, 96);
        let mut shared = mk_cache(&geo, pool, true);
        let mut unshared = mk_cache(&geo, 4096, false);
        shared.parallel = *g.choose(&[ParallelPolicy::Off, ParallelPolicy::Auto]);
        let mut ws = GatherWorkspace::new();

        // base prompts the ops draw shared prefixes from
        let bases: Vec<Vec<i32>> = (0..3)
            .map(|b| {
                let n = g.usize_in(2 * cfg.tokens_per_page, 6 * cfg.tokens_per_page);
                (0..n).map(|i| (b * 1000 + i) as i32).collect()
            })
            .collect();

        // live sequences: (seq, full token stream so far, prompt_len)
        let mut live: Vec<(u64, Vec<i32>, usize)> = Vec::new();
        let mut next_seq = 0u64;
        let mut next_tok = 50_000i32;

        for _ in 0..30 {
            match g.usize_in(0, 3) {
                // admit a sequence whose prompt is a prefix of a base
                // prompt (often shared), sometimes with a twist
                0 => {
                    let base = g.choose(&bases).clone();
                    let plen = g.usize_in(1, base.len());
                    let mut prompt = base[..plen].to_vec();
                    if g.bool() && g.bool() {
                        // diverge mid-prompt: exercises partial hits
                        let i = g.usize_in(0, plen - 1);
                        prompt[i] = next_tok;
                        next_tok += 1;
                    }
                    if !shared.can_admit_prompt(&prompt, prompt.len()) {
                        continue; // pool full even after reuse: skip
                    }
                    next_seq += 1;
                    let reuse = shared
                        .start_seq_with_prompt(next_seq, &prompt)
                        .map_err(|e| e.to_string())?;
                    if reuse.tokens > prompt.len() {
                        return Err(format!("reuse {} > prompt {}", reuse.tokens, prompt.len()));
                    }
                    // append only the part adoption didn't cover
                    let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
                    shared
                        .append_run(next_seq, &k, &v, prompt.len() - reuse.tokens)
                        .map_err(|e| format!("admitted but append failed: {e}"))?;
                    unshared.start_seq(next_seq).map_err(|e| e.to_string())?;
                    let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
                    unshared
                        .append_run(next_seq, &k, &v, prompt.len())
                        .map_err(|e| e.to_string())?;
                    live.push((next_seq, prompt, plen));
                }
                // decode append (CoW when the tail is a shared page)
                1 if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, stream, _) = &mut live[i];
                    stream.push(next_tok);
                    next_tok += 1;
                    let t = stream.len() - 1;
                    let (k, v) = kv_at(stream, t, &cfg);
                    match shared.append_token(*seq, &k, &v) {
                        Ok(()) => {
                            unshared
                                .append_token(*seq, &k, &v)
                                .map_err(|e| e.to_string())?;
                        }
                        Err(_) => {
                            // pool exhausted: keep streams aligned
                            stream.pop();
                        }
                    }
                }
                // drop
                2 if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, _, _) = live.swap_remove(i);
                    shared.drop_seq(seq);
                    unshared.drop_seq(seq);
                }
                // verify a random live sequence through every path
                _ if !live.is_empty() => {
                    let i = g.rng.below(live.len());
                    let (seq, stream, _) = &live[i];
                    verify_seq(&shared, &unshared, *seq, stream.len(), &cfg, &mut ws)?;
                }
                _ => {}
            }
        }

        // final sweep: every live sequence still byte-identical
        for (seq, stream, _) in &live {
            verify_seq(&shared, &unshared, *seq, stream.len(), &cfg, &mut ws)?;
        }

        // teardown: all ownerships return to zero (zero-ref cached
        // pages may stay resident — they are owned by nobody)
        for (seq, _, _) in live.drain(..) {
            shared.drop_seq(seq);
            unshared.drop_seq(seq);
        }
        if shared.live_refs() != 0 {
            return Err(format!("{} refs leaked", shared.live_refs()));
        }
        if shared.live_pages() != 0 {
            return Err(format!("{} live pages leaked", shared.live_pages()));
        }
        if unshared.pages_in_use() != 0 {
            return Err("unshared cache leaked pages".into());
        }
        Ok(())
    });
}

/// Persist → restart → byte-identical gather, as a property over
/// random geometries and prompt mixes: whatever a first boot published
/// and spilled, a second boot (fresh cache, same persist dir) must
/// adopt without re-encoding — covering the *entire* prompt (every
/// prompt page of a completed prompt is published, parked, and spilled
/// on drop) — and reconstruct bit-for-bit what an unshared,
/// never-persisted reference cache produces.
#[test]
fn prop_persist_restart_gathers_byte_identical() {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    check(10, 0x7E57, |g| {
        let geo = geometry(g);
        let cfg = geo.cfg;
        let dir = std::env::temp_dir().join(format!(
            "isoquant-prefix-persist-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let attach = |m: &mut CacheManager| {
            let store = PageStore::open(StoreConfig::for_cache(
                dir.clone(),
                m.fingerprint(),
                m.page_cfg().page_bytes(),
                0,
            ))
            .map_err(|e| e.to_string())?;
            m.attach_store(store);
            Ok::<(), String>(())
        };
        // prompts: random prefixes of a base stream (often overlapping)
        let base: Vec<i32> = (0..6 * cfg.tokens_per_page as i32).collect();
        let n_prompts = g.usize_in(1, 3);
        let prompts: Vec<Vec<i32>> = (0..n_prompts)
            .map(|_| base[..g.usize_in(1, base.len())].to_vec())
            .collect();

        // ---- boot 1: populate, decode a little, drop, spill --------
        let mut first = mk_cache(&geo, 4096, true);
        attach(&mut first)?;
        let mut unshared = mk_cache(&geo, 4096, false);
        for (i, prompt) in prompts.iter().enumerate() {
            let seq = i as u64 + 1;
            let reuse = first
                .start_seq_with_prompt(seq, prompt)
                .map_err(|e| e.to_string())?;
            let (k, v) = kv_run(prompt, reuse.tokens, prompt.len(), &cfg);
            first
                .append_run(seq, &k, &v, prompt.len() - reuse.tokens)
                .map_err(|e| e.to_string())?;
            unshared.start_seq(seq).map_err(|e| e.to_string())?;
            let (k, v) = kv_run(prompt, 0, prompt.len(), &cfg);
            unshared
                .append_run(seq, &k, &v, prompt.len())
                .map_err(|e| e.to_string())?;
            // a few decode tokens (CoW off the published tail)
            if g.bool() {
                let mut stream = prompt.clone();
                for d in 0..g.usize_in(1, 3) {
                    stream.push(90_000 + (i * 100 + d) as i32);
                    let (tk, tv) = kv_at(&stream, stream.len() - 1, &cfg);
                    first.append_token(seq, &tk, &tv).map_err(|e| e.to_string())?;
                }
            }
            first.drop_seq(seq);
        }
        first.flush_store();
        let spilled = first.share.pages_spilled;
        drop(first);
        if spilled == 0 {
            return Err("nothing spilled — the property would be vacuous".into());
        }

        // ---- boot 2: fresh cache, same dir ------------------------
        let mut second = mk_cache(&geo, 4096, true);
        attach(&mut second)?;
        if second.share.pages_rehydrated == 0 {
            return Err("nothing rehydrated".into());
        }
        let mut ws = GatherWorkspace::new();
        for (i, prompt) in prompts.iter().enumerate() {
            let seq = i as u64 + 1;
            let reuse = second
                .start_seq_with_prompt(seq, prompt)
                .map_err(|e| e.to_string())?;
            // every page of a completed prompt was published + spilled:
            // the warm boot must cover the whole prompt without
            // re-encoding a single token
            if reuse.tokens != prompt.len() {
                return Err(format!(
                    "prompt {i}: warm boot reused {}/{} tokens",
                    reuse.tokens,
                    prompt.len()
                ));
            }
            verify_seq(&second, &unshared, seq, prompt.len(), &cfg, &mut ws)?;
        }
        if second.share.pages_promoted == 0 {
            return Err("no promotions on a warm boot".into());
        }
        for i in 0..prompts.len() {
            second.drop_seq(i as u64 + 1);
        }
        if second.live_refs() != 0 {
            return Err("refs leaked across restart".into());
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Cross-lane gather dedup is a pure bandwidth optimization: over any
/// mix of lanes whose prompts share prefix pages (plus divergent decode
/// tails), a batched multi-lane gather with `gather_dedup` on must be
/// byte-identical — f32 and f16 output alike — to the same gather with
/// it off, and the dedup counters must move only when the knob is on.
#[test]
fn prop_gather_dedup_byte_identical_across_lanes() {
    use std::sync::atomic::Ordering;
    check(12, 0xDED0, |g| {
        let geo = geometry(g);
        let cfg = geo.cfg;
        let mut cache = mk_cache(&geo, 4096, true);
        cache.parallel = *g.choose(&[ParallelPolicy::Off, ParallelPolicy::Auto]);

        let base: Vec<i32> = (0..6 * cfg.tokens_per_page as i32).collect();
        let n_lanes = g.usize_in(2, 5);
        let mut streams: Vec<Vec<i32>> = Vec::new();
        for lane in 0..n_lanes {
            // the first two lanes always cover at least one full base
            // page so the dedup plan is guaranteed to find shared work;
            // the rest draw arbitrary (possibly sub-page) prefixes
            let plen = if lane < 2 {
                g.usize_in(cfg.tokens_per_page, base.len())
            } else {
                g.usize_in(1, base.len())
            };
            let prompt = base[..plen].to_vec();
            let seq = lane as u64 + 1;
            let reuse = cache
                .start_seq_with_prompt(seq, &prompt)
                .map_err(|e| e.to_string())?;
            let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
            cache
                .append_run(seq, &k, &v, prompt.len() - reuse.tokens)
                .map_err(|e| e.to_string())?;
            // divergent decode tail
            let mut stream = prompt;
            for d in 0..g.usize_in(0, 3) {
                stream.push(70_000 + (lane * 100 + d) as i32);
                let (tk, tv) = kv_at(&stream, stream.len() - 1, &cfg);
                cache
                    .append_token(seq, &tk, &tv)
                    .map_err(|e| e.to_string())?;
            }
            streams.push(stream);
        }

        let pairs: Vec<(u64, usize)> =
            (0..n_lanes).map(|lane| (lane as u64 + 1, lane)).collect();
        let t_max = streams.iter().map(|s| s.len()).max().unwrap() + g.usize_in(0, 2);
        let sz = cfg.n_layers * n_lanes * cfg.n_heads * t_max * cfg.d_head;
        let mut ws = GatherWorkspace::new();

        cache.gather_dedup = false;
        let (mut ka, mut va) = (vec![3.0f32; sz], vec![3.0f32; sz]);
        let na = cache
            .gather_lanes_into_batch_ws(&pairs, n_lanes, t_max, &mut ka, &mut va, &mut ws)
            .map_err(|e| e.to_string())?;
        let (mut kha, mut vha) = (vec![7u16; sz], vec![7u16; sz]);
        cache
            .gather_lanes_into_batch_f16_ws(&pairs, n_lanes, t_max, &mut kha, &mut vha, &mut ws)
            .map_err(|e| e.to_string())?;
        if cache.share.strips_deduped.load(Ordering::Relaxed) != 0 {
            return Err("dedup counters moved with the knob off".into());
        }

        cache.gather_dedup = true;
        let (mut kb, mut vb) = (vec![4.0f32; sz], vec![4.0f32; sz]);
        let nb = cache
            .gather_lanes_into_batch_ws(&pairs, n_lanes, t_max, &mut kb, &mut vb, &mut ws)
            .map_err(|e| e.to_string())?;
        let (mut khb, mut vhb) = (vec![8u16; sz], vec![8u16; sz]);
        cache
            .gather_lanes_into_batch_f16_ws(&pairs, n_lanes, t_max, &mut khb, &mut vhb, &mut ws)
            .map_err(|e| e.to_string())?;

        if na != nb {
            return Err(format!("lane lengths changed under dedup: {na:?} vs {nb:?}"));
        }
        if bits_of(&ka) != bits_of(&kb) || bits_of(&va) != bits_of(&vb) {
            return Err("f32 gather differs with dedup on".into());
        }
        if kha != khb || vha != vhb {
            return Err("f16 gather differs with dedup on".into());
        }
        // lanes 0 and 1 both own base page 0, so both the f32 and f16
        // dedup'd drains found at least one follower strip each
        if cache.share.strips_deduped.load(Ordering::Relaxed) == 0 {
            return Err("no strips deduped despite a guaranteed shared page".into());
        }
        if cache.share.bytes_saved.load(Ordering::Relaxed) == 0 {
            return Err("strips deduped but no bytes accounted".into());
        }

        for lane in 0..n_lanes {
            cache.drop_seq(lane as u64 + 1);
        }
        if cache.live_refs() != 0 {
            return Err("refs leaked".into());
        }
        Ok(())
    });
}

#[test]
fn burst_of_same_prompt_sequences_allocates_shared_prefix_once() {
    // the manager-level acceptance check: 64 same-prompt sequences on a
    // shared cache allocate the prefix pages once (+ per-seq tails),
    // where the unshared cache pays for everything 64 times
    let geo = Geometry {
        cfg: PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 32,
            encoded_len: Stage1::new(Stage1Config::new(Variant::IsoFull, 32, 3)).encoded_len(),
        },
        bits: 3,
    };
    let cfg = geo.cfg;
    let mut shared = mk_cache(&geo, 4096, true);
    let mut unshared = mk_cache(&geo, 4096, false);
    let prompt: Vec<i32> = (0..18).collect(); // 4 full pages + tail of 2
    let clients = 64u64;
    let decode_per_seq = 3usize;

    let mut streams = Vec::new();
    for seq in 1..=clients {
        let reuse = shared.start_seq_with_prompt(seq, &prompt).unwrap();
        if seq == 1 {
            assert_eq!(reuse.pages, 0, "first client is cold");
        } else {
            assert_eq!(reuse.pages, 5, "followers adopt 4 full pages + tail");
            assert_eq!(reuse.tokens, prompt.len());
        }
        let (k, v) = kv_run(&prompt, reuse.tokens, prompt.len(), &cfg);
        shared
            .append_run(seq, &k, &v, prompt.len() - reuse.tokens)
            .unwrap();
        unshared.start_seq(seq).unwrap();
        let (k, v) = kv_run(&prompt, 0, prompt.len(), &cfg);
        unshared.append_run(seq, &k, &v, prompt.len()).unwrap();
        // a few decode tokens, unique per sequence
        let mut stream = prompt.clone();
        for d in 0..decode_per_seq {
            stream.push(100_000 + (seq as i32) * 10 + d as i32);
            let t = stream.len() - 1;
            let (k, v) = kv_at(&stream, t, &cfg);
            shared.append_token(seq, &k, &v).unwrap();
            unshared.append_token(seq, &k, &v).unwrap();
        }
        streams.push(stream);
    }

    // page accounting: prompt spans 5 pages. Shared: 4 full pages once,
    // + the sealed tail once (cached after the CoW dance), + per seq
    // {CoW tail + 1 overflow page for tokens 20..21}.  Unshared: 6
    // pages per sequence.
    let shared_prefix_pages = 5;
    let per_seq_tail_pages = 2; // CoW'd tail + overflow page
    assert_eq!(
        unshared.pages_in_use(),
        clients as usize * 6,
        "unshared pays full freight"
    );
    assert!(
        shared.pages_in_use()
            <= shared_prefix_pages + clients as usize * per_seq_tail_pages,
        "shared run must not duplicate the prefix: {} pages",
        shared.pages_in_use()
    );
    assert_eq!(shared.share.prefix_hit_pages, (clients - 1) * 5);
    assert_eq!(shared.share.cow_copies, clients);

    // byte-identical reconstructions for every client
    let mut ws = GatherWorkspace::new();
    for (i, stream) in streams.iter().enumerate() {
        verify_seq(&shared, &unshared, i as u64 + 1, stream.len(), &cfg, &mut ws).unwrap();
    }

    for seq in 1..=clients {
        shared.drop_seq(seq);
        unshared.drop_seq(seq);
    }
    assert_eq!(shared.live_refs(), 0);
    assert_eq!(shared.live_pages(), 0);
    assert_eq!(unshared.pages_in_use(), 0);
}
