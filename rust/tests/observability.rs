//! Observability integration: the `/metrics` exposition lints clean
//! (including under concurrent streaming load, scraped over a raw
//! socket exactly like Prometheus would), per-request trace timelines
//! are monotone and complete for every outcome, the flight recorder
//! dump works over the wire, and serve-path latency memory stays
//! O(buckets) no matter how many samples flow.
//!
//! The in-process tests always run; the TCP tests need `make artifacts`
//! and SKIP (pass trivially, with a note) when artifacts are absent so
//! `cargo test` works in a fresh checkout.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use isoquant::config::EngineConfig;
use isoquant::coordinator::Engine;
use isoquant::metrics::prometheus::{lint_exposition, render_prometheus, MetricsSnapshot};
use isoquant::metrics::{Counters, Histogram, LatencyRecorder, ShareStats};
use isoquant::runtime::ServingModel;
use isoquant::server::{serve_on, Client, ServeReport};
use isoquant::util::json::Json;

/// The XLA CPU runtime does not tolerate concurrent PJRT client
/// creation in one process; serialize everything that touches PJRT.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = isoquant::runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts not built; skipping observability TCP tests");
        None
    }
}

// ---------------------------------------------------------------------
// always-run: exposition shape, field-table completeness, bounded memory
// ---------------------------------------------------------------------

/// A populated snapshot rendered through the public API must lint clean
/// and carry every counter both field tables know about — the
/// completeness check that keeps a newly added counter from silently
/// missing the exposition.
#[test]
fn exposition_lints_and_covers_field_tables() {
    let h = Histogram::new();
    for v in [90.0, 1_500.0, 42_000.0, 2e6] {
        h.record_us(v);
    }
    let mut snap = MetricsSnapshot::default();
    snap.share.prefix_hit_pages = 12;
    snap.share.requests_shed = 2;
    snap.share.store_degraded = 1;
    snap.counters = Counters::default().fields();
    snap.compression_ratio = 7.5;
    snap.pages.live = 9;
    snap.pages.capacity = 64;
    snap.conn_overflow_disconnects = 3;
    snap.hists = vec![
        ("isoquant_ttft_seconds", h.snapshot()),
        ("isoquant_decode_step_seconds", h.snapshot()),
    ];
    snap.phases = vec![("forward", h.snapshot()), ("emit", Histogram::new().snapshot())];

    let text = render_prometheus(&snap);
    lint_exposition(&text).expect("rendered exposition lints clean");
    for (name, _) in ShareStats::default().fields() {
        assert!(
            text.contains(name),
            "share field {name} missing from exposition"
        );
    }
    for (name, _) in Counters::default().fields() {
        assert!(
            text.contains(&format!("isoquant_{name}_total")),
            "counter {name} missing from exposition"
        );
    }
    for required in [
        "isoquant_compression_ratio 7.5",
        "isoquant_store_degraded 1",
        "isoquant_conn_overflow_disconnects_total 3",
        "isoquant_pages_live 9",
        "isoquant_ttft_seconds_bucket",
        "isoquant_ttft_seconds_sum",
        "isoquant_ttft_seconds_count 4",
        "isoquant_engine_phase_seconds_bucket{phase=\"forward\"",
        "isoquant_engine_phase_seconds_count{phase=\"emit\"} 0",
    ] {
        assert!(text.contains(required), "{required} missing:\n{text}");
    }
}

/// The serve-path latency stores are bounded: recording a million
/// samples allocates nothing per sample, and a percentile query walks
/// buckets, not samples.  (The old keep-every-sample recorder cloned
/// and sorted all samples per query — the regression this pins down.)
#[test]
fn latency_memory_and_queries_are_o_buckets() {
    let h = Histogram::new();
    for i in 0..1_000_000u64 {
        h.record_us(1.0 + (i % 100_000) as f64);
    }
    // fixed-size type: 64 buckets + the sum, no sample storage anywhere
    assert_eq!(
        std::mem::size_of::<Histogram>(),
        std::mem::size_of::<u64>() * (isoquant::metrics::histogram::BUCKETS + 1)
    );
    assert_eq!(h.count(), 1_000_000);
    // 10k queries over a million-sample histogram: O(buckets) each.
    // The bound is deliberately generous (no flaky timing), but an
    // accidental clone-and-sort-per-query regression (~minutes) trips it.
    let t0 = Instant::now();
    let mut acc = 0.0;
    for i in 0..10_000 {
        acc += h.percentile(50.0 + (i % 50) as f64);
    }
    assert!(acc > 0.0);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "percentile queries look O(samples), not O(buckets): {:?}",
        t0.elapsed()
    );
}

/// Histogram percentiles must agree with the exact keep-every-sample
/// recorder to within one bucket width (ratio √2) — the accuracy
/// contract the serve path traded sample storage for.
#[test]
fn histogram_agrees_with_latency_recorder_within_one_bucket() {
    let h = Histogram::new();
    let mut r = LatencyRecorder::new();
    for i in 0..50_000u64 {
        // deterministic spread over ~5 orders of magnitude
        let v = 2.0 + ((i as f64 * 131.0) % 250_000.0);
        h.record_us(v);
        r.record_us(v);
    }
    for p in [50.0, 90.0, 95.0, 99.0, 99.9] {
        let exact = r.percentile(p);
        let est = h.percentile(p);
        assert!(
            est >= exact / 2f64.sqrt() - 1e-9 && est <= exact * 2f64.sqrt() + 1e-9,
            "p{p}: histogram {est} vs exact {exact} differ by more than one bucket"
        );
    }
}

// ---------------------------------------------------------------------
// TCP tests (artifacts-gated)
// ---------------------------------------------------------------------

struct TestServer {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<ServeReport>>,
}

impl TestServer {
    /// Boot a server on an ephemeral port; `tweak` adjusts the config
    /// before the engine is built (the PJRT client is !Send, so the
    /// engine lives on the server thread).
    fn boot(dir: &PathBuf, tweak: impl FnOnce(&mut EngineConfig) + Send + 'static) -> TestServer {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_srv = stop.clone();
        let dir_srv = dir.clone();
        let thread = std::thread::spawn(move || {
            let model = ServingModel::load(&dir_srv).expect("load model");
            let mut cfg = EngineConfig::default();
            tweak(&mut cfg);
            let engine = Engine::new(model, cfg).expect("boot engine");
            serve_on(engine, listener, stop_srv).expect("serve")
        });
        TestServer { addr, stop, thread: Some(thread) }
    }

    fn shutdown(mut self) -> ServeReport {
        self.stop.store(true, Ordering::SeqCst);
        self.thread.take().unwrap().join().unwrap()
    }
}

/// Scrape `/metrics` over a raw socket, exactly like Prometheus: one
/// HTTP GET, read to EOF (the server closes after the response).
/// Returns (status line, body).
fn raw_scrape(addr: &str, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect for scrape");
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nAccept: */*\r\n\r\n").unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut resp = Vec::new();
    s.read_to_end(&mut resp).expect("read scrape response");
    let resp = String::from_utf8(resp).expect("scrape response is UTF-8");
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .expect("HTTP header/body separator");
    let status = head.lines().next().unwrap_or("").to_string();
    // Content-Length must frame the body exactly
    let clen: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .expect("Content-Length header")
        .trim()
        .parse()
        .expect("numeric Content-Length");
    assert_eq!(clen, body.len(), "Content-Length does not frame the body");
    (status, body.to_string())
}

/// The non-negative stamps of a trace object, in lifecycle order, must
/// be monotone non-decreasing; `-1` marks a stage the request never
/// reached.
fn assert_trace_monotone(tr: &Json, ctx: &str) {
    let mut prev = 0.0f64;
    for key in [
        "received",
        "parsed",
        "queued",
        "admitted",
        "prefix_walk",
        "prefill_done",
        "first_token",
        "finished",
    ] {
        let us = tr
            .get(key)
            .unwrap_or_else(|| panic!("{ctx}: trace missing {key}"))
            .as_f64()
            .unwrap_or_else(|| panic!("{ctx}: trace {key} not a number"));
        if us >= 0.0 {
            assert!(
                us >= prev,
                "{ctx}: {key} offset {us} precedes previous stamp {prev}"
            );
            prev = us;
        }
    }
    assert!(
        tr.get("finished").unwrap().as_f64().unwrap() >= 0.0,
        "{ctx}: every terminal trace carries a finished stamp"
    );
}

/// The headline integration: 8 concurrent streaming clients, raw-socket
/// scrapes racing them, a wire trace for a finished request, a timeout
/// trace, a cancelled request surfacing in the flight-recorder dump,
/// and the step profiler showing up in both surfaces.
#[test]
fn scrape_and_traces_during_streaming_load() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = TestServer::boot(&dir, |cfg| {
        cfg.profile = true;
    });

    // -- streaming load + concurrent scrapes ---------------------------
    let n_clients = 8usize;
    let clients: Vec<_> = (0..n_clients)
        .map(|i| {
            let addr = srv.addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let prompt: Vec<i32> = (0..16).map(|t| (t * 3) % 40 + 1).collect();
                let req = format!(
                    "{{\"id\": {}, \"prompt\": {:?}, \"max_new_tokens\": 8, \"stream\": true}}",
                    i + 1,
                    prompt
                );
                c.send_line(&req).expect("send");
                let mut tokens = 0usize;
                loop {
                    let v = c.recv().expect("stream line");
                    if v.get("finish").is_some() {
                        assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));
                        break;
                    }
                    assert!(v.get("token").is_some(), "line is token or terminal");
                    tokens += 1;
                }
                tokens
            })
        })
        .collect();
    // scrape while the load is in flight — a scrape must neither block
    // on the engine nor return something malformed mid-step
    let mut scrapes = 0usize;
    while scrapes < 5 {
        let (status, body) = raw_scrape(&srv.addr, "/metrics");
        assert!(status.contains("200"), "scrape failed: {status}");
        lint_exposition(&body).unwrap_or_else(|e| panic!("mid-load scrape lint: {e}"));
        scrapes += 1;
    }
    for (i, c) in clients.into_iter().enumerate() {
        let tokens = c.join().unwrap();
        assert_eq!(tokens, 8, "client {i} lost streamed tokens to the scrapes");
    }

    // -- the post-load scrape carries the load's counters --------------
    // (the exposition refreshes ~1/s; poll briefly for the new snapshot)
    let deadline = Instant::now() + Duration::from_secs(10);
    let body = loop {
        let (_, body) = raw_scrape(&srv.addr, "/metrics");
        let reqs = body
            .lines()
            .find_map(|l| l.strip_prefix("isoquant_requests_total "))
            .and_then(|v| v.trim().parse::<f64>().ok())
            .unwrap_or(0.0);
        if reqs >= n_clients as f64 || Instant::now() > deadline {
            break body;
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    lint_exposition(&body).expect("post-load scrape lints");
    for required in [
        "isoquant_requests_total",
        "isoquant_tokens_decoded_total",
        "isoquant_share_prefix_hit_pages_total",
        "isoquant_compression_ratio",
        "isoquant_pages_live",
        "isoquant_pages_capacity",
        "isoquant_store_attached 0",
        "isoquant_conn_overflow_disconnects_total",
        "isoquant_ttft_seconds_bucket",
        "isoquant_decode_step_seconds_count",
        "isoquant_queue_wait_seconds_bucket",
        "isoquant_request_total_seconds_bucket",
        // profile = on: the phase histograms are exported
        "isoquant_engine_phase_seconds_bucket{phase=\"forward\"",
        "isoquant_engine_phase_seconds_bucket{phase=\"gather\"",
    ] {
        assert!(body.contains(required), "{required} missing from scrape");
    }
    let reqs = body
        .lines()
        .find_map(|l| l.strip_prefix("isoquant_requests_total "))
        .and_then(|v| v.trim().parse::<f64>().ok())
        .unwrap_or(0.0);
    assert!(
        reqs >= n_clients as f64,
        "scrape never caught up with the load: requests_total = {reqs}"
    );
    // unknown paths 404 without disturbing the connection protocol
    let (status, _) = raw_scrape(&srv.addr, "/nope");
    assert!(status.contains("404"), "unknown path must 404: {status}");

    // -- wire trace: finished request ----------------------------------
    let mut c = Client::connect(&srv.addr).expect("connect");
    c.send_line(r#"{"id": 900, "prompt": [5, 6, 7, 8], "max_new_tokens": 4, "trace": true}"#)
        .unwrap();
    let v = c.recv().expect("traced completion");
    assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));
    let tr = v.get("trace").expect("trace field on opted-in completion");
    assert_trace_monotone(tr, "finished");
    // wire-submitted: the reactor stamped the front of the pipeline
    assert_eq!(tr.get("received").unwrap().as_f64(), Some(0.0));
    assert!(tr.get("parsed").unwrap().as_f64().unwrap() >= 0.0);
    assert!(tr.get("admitted").unwrap().as_f64().unwrap() >= 0.0);
    assert!(tr.get("first_token").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(tr.get("outcome").unwrap().as_str(), Some("max_tokens"));
    // an untraced request on the same connection stays byte-compatible
    c.send_line(r#"{"id": 901, "prompt": [5, 6, 7, 8], "max_new_tokens": 2}"#)
        .unwrap();
    let v = c.recv().unwrap();
    assert!(v.get("trace").is_none(), "trace must be strictly opt-in");

    // -- wire trace: timeout -------------------------------------------
    c.send_line(
        r#"{"id": 902, "prompt": [9, 10, 11, 12], "max_new_tokens": 64, "deadline_ms": 1, "trace": true}"#,
    )
    .unwrap();
    let v = c.recv().expect("timeout completion");
    assert_eq!(v.get("finish").unwrap().as_str(), Some("timeout"));
    let tr = v.get("trace").expect("trace on timeout");
    assert_trace_monotone(tr, "timeout");
    assert_eq!(tr.get("outcome").unwrap().as_str(), Some("timeout"));

    // -- cancelled requests reach the flight recorder ------------------
    {
        let mut doomed = Client::connect(&srv.addr).expect("connect doomed");
        doomed
            .send_line(r#"{"id": 903, "prompt": [1, 2, 3], "max_new_tokens": 64}"#)
            .unwrap();
        // dropping the connection cancels the in-flight request
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    let cancelled = loop {
        c.send_line(r#"{"stats": true, "traces": 64}"#).unwrap();
        let stats = c.recv().expect("stats");
        let traces = stats
            .get("traces")
            .expect("traces array when requested")
            .as_arr()
            .expect("traces is an array")
            .to_vec();
        let hit = traces.iter().find(|t| {
            t.get("outcome").and_then(|o| o.as_str()) == Some("cancelled")
        });
        if let Some(t) = hit {
            break t.clone();
        }
        assert!(
            Instant::now() < deadline,
            "cancelled request never reached the flight recorder"
        );
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_trace_monotone(&cancelled, "cancelled");
    assert_eq!(cancelled.get("id").unwrap().as_usize(), Some(903));

    // -- stats carries the profiler and histogram latencies ------------
    c.send_line(r#"{"stats": true}"#).unwrap();
    let stats = c.recv().unwrap();
    assert!(stats.get("traces").is_none(), "traces only when asked");
    let latency = stats.get("latency").expect("latency section");
    for key in ["ttft_us", "inter_token_us", "queue_wait_us", "request_total_us"] {
        let l = latency.get(key).unwrap_or_else(|| panic!("{key} missing"));
        assert!(l.get("n").unwrap().as_f64().unwrap() >= 0.0);
    }
    let phases = latency
        .get("engine_phases_us")
        .expect("engine_phases_us with profile = on");
    for phase in ["expire", "admit", "gather", "forward", "append", "emit"] {
        assert!(phases.get(phase).is_some(), "phase {phase} missing");
    }

    let report = srv.shutdown();
    assert_eq!(report.undrained_lanes, 0, "drain left lanes active");
    assert!(report.share.requests_cancelled >= 1, "cancel was recorded");
    assert!(report.share.requests_timed_out >= 1, "timeout was recorded");
}

/// Overload shedding leaves a complete timeline behind: a pipelined
/// burst against a 1-slot queue sheds most of it, every line is
/// answered, and shed requests appear in the flight recorder with a
/// finished stamp but no admission.
#[test]
fn shed_requests_leave_traces() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    let srv = TestServer::boot(&dir, |cfg| {
        cfg.max_queue = 1;
    });

    let mut c = Client::connect(&srv.addr).expect("connect");
    let burst = 16usize;
    let mut lines = String::new();
    for i in 0..burst {
        lines.push_str(&format!(
            "{{\"id\": {}, \"prompt\": [2, 4, 6], \"max_new_tokens\": 8, \"trace\": true}}\n",
            i + 1
        ));
    }
    c.send_line(lines.trim_end()).expect("pipelined burst");
    let (mut completed, mut shed) = (0usize, 0usize);
    for _ in 0..burst {
        let v = c.recv().expect("every burst line gets an answer");
        if v.get("error").is_some() {
            assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
            assert!(v.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
            shed += 1;
        } else {
            assert!(v.get("finish").is_some());
            completed += 1;
        }
    }
    assert_eq!(completed + shed, burst);
    assert!(
        shed >= 1,
        "a {burst}-deep burst against max_queue=1 must shed (completed={completed})"
    );

    // the flight recorder kept the shed requests' timelines
    c.send_line(r#"{"stats": true, "traces": 64}"#).unwrap();
    let stats = c.recv().unwrap();
    let traces = stats.get("traces").unwrap().as_arr().unwrap().to_vec();
    let shed_traces: Vec<_> = traces
        .iter()
        .filter(|t| t.get("outcome").and_then(|o| o.as_str()) == Some("shed"))
        .collect();
    assert!(
        !shed_traces.is_empty(),
        "shed requests missing from the flight recorder"
    );
    for t in &shed_traces {
        assert_trace_monotone(t, "shed");
        // shed at admission control: never admitted, but terminally stamped
        assert_eq!(t.get("admitted").unwrap().as_f64(), Some(-1.0));
        assert!(t.get("finished").unwrap().as_f64().unwrap() >= 0.0);
    }

    let report = srv.shutdown();
    assert_eq!(report.share.requests_shed as usize, shed, "shed accounting");
}

/// The dedicated `[server] metrics_addr` listener serves scrapes on its
/// own port while the main port keeps talking JSON lines.
#[test]
fn dedicated_metrics_listener_serves_scrapes() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts_dir() else { return };
    // grab a free port for the metrics listener (bind, read, release)
    let probe = TcpListener::bind("127.0.0.1:0").unwrap();
    let maddr = probe.local_addr().unwrap().to_string();
    drop(probe);
    let maddr_cfg = maddr.clone();
    let srv = TestServer::boot(&dir, move |cfg| {
        cfg.metrics_addr = maddr_cfg;
    });
    // the reactor may need a beat to register the second listener
    let deadline = Instant::now() + Duration::from_secs(10);
    let body = loop {
        match TcpStream::connect(&maddr) {
            Ok(_) => break raw_scrape(&maddr, "/metrics").1,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(50))
            }
            Err(e) => panic!("metrics listener never came up: {e}"),
        }
    };
    lint_exposition(&body).expect("dedicated-port scrape lints");
    assert!(body.contains("isoquant_pages_capacity"));

    // the main port still serves generation
    let mut c = Client::connect(&srv.addr).expect("connect main");
    let v = c.generate(1, &[3, 5, 7], 2).expect("generate");
    assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));

    let report = srv.shutdown();
    assert_eq!(report.undrained_lanes, 0);
}
