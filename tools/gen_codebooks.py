"""Regenerate rust/src/quant/codebooks.rs from the python Lloyd-Max
trainer (the cross-language parity contract). Run via `make codebooks`."""
from compile.kernels.quantizer import lloyd_max_codebook, gaussian_codebook

print('''//! Lloyd–Max codebooks, trained offline in python
//! (`python/compile/kernels/quantizer.py`) on the analytic marginal
//! f_k(z) ∝ (1-z²)^((k-3)/2) of a Haar-rotated block coordinate
//! (paper eq. 36), scaled by √k.  These constants are the cross-language
//! parity contract: the Pallas kernels bake the same values into the AOT
//! HLO, and `python/tests/test_quantizer.py` pins the trainer output.
//! Regenerate with `make codebooks`.

/// codebook for (block size k, bits b); levels are sorted ascending.
pub fn lloyd_codebook(k: usize, bits: u8) -> &'static [f32] {
    match (k, bits) {''')
for k in (2, 3, 4):
    for b in (2, 3, 4):
        cb = lloyd_max_codebook(k, b)
        vals = ', '.join(f'{float(v):.9}' for v in cb)
        print(f'        ({k}, {b}) => &[{vals}],')
print('''        _ => panic!("no codebook trained for k={k} bits={bits}"),
    }
}

/// classic Lloyd–Max codebook for N(0,1) (used by the grouped-8D variant
/// and by unnormalized ablations).
pub fn gaussian_lloyd_codebook(bits: u8) -> &'static [f32] {
    match bits {''')
for b in (2, 3, 4):
    cb = gaussian_codebook(b)
    vals = ', '.join(f'{float(v):.9}' for v in cb)
    print(f'        {b} => &[{vals}],')
print('''        _ => panic!("no gaussian codebook for bits={bits}"),
    }
}''')
