"""Scalar quantizers shared by every rotation variant.

The paper's stage-1 pipeline quantizes each coordinate of the *rotated,
normalized* vector with a per-coordinate scalar quantizer (Lloyd–Max in
the prototype, §7.2).  Two quantizers are provided:

* ``uniform`` — symmetric mid-rise uniform quantizer on ``[-c, c]``.
* ``lloyd_max`` — codebook quantizer whose levels are trained offline by
  Lloyd iteration on the analytic marginal of a rotated coordinate
  (paper eq. 36): for block size ``k`` the normalized coordinate has
  density ``f_k(z) ∝ (1 - z^2)^((k-3)/2)`` scaled by the block radius.

Codebooks are expressed as plain Python floats so that they embed as
compile-time constants into both the Pallas kernels and the lowered HLO,
and so that the Rust native path (rust/src/quant/scalar.rs) can ship the
byte-identical tables (cross-checked by the parity tests).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Offline Lloyd–Max training on the analytic marginal f_k
# --------------------------------------------------------------------------

def marginal_samples(k: int, n: int = 200_001) -> np.ndarray:
    """Deterministic quantile samples of the rotated-coordinate marginal.

    For a coordinate of a Haar-rotated k-dim block with unit radius the
    marginal density is f_k(z) ∝ (1 - z^2)^((k-3)/2) on [-1, 1]
    (paper eq. 36; k=2 arcsine, k=4 semicircle-like).  We sample by
    inverse-CDF on a dense grid, which keeps training deterministic.

    In the pipeline each *block* has radius r_b ≈ sqrt(k/d) for a
    normalized d-vector, so coordinates live at scale ~1/sqrt(d); the
    quantizer is applied to sqrt(d)-scaled coordinates (see
    ``QuantSpec``) which makes one codebook serve every d.
    """
    u = np.linspace(0.0, 1.0, n + 2)[1:-1]
    if k == 2:
        # arcsine law: F(z) = 1/2 + arcsin(z)/π → z = sin(π(u - 1/2));
        # analytic inversion avoids the grid bias at the singular edges
        z = np.sin(np.pi * (u - 0.5))
    elif k == 3:
        # f_3 is uniform on [-1, 1]
        z = 2.0 * u - 1.0
    else:
        grid = np.linspace(-1.0, 1.0, 400_000)
        pdf = np.maximum(1.0 - grid**2, 0.0) ** ((k - 3) / 2.0)
        cdf = np.cumsum(pdf)
        cdf = cdf / cdf[-1]
        z = np.interp(u, cdf, grid)
    # scale: coordinate of a k-block with radius sqrt(k) (so that the
    # sqrt(d)-scaled coordinate of a normalized d-vector matches:
    # sqrt(d) * r_b / sqrt(k) * z with r_b ≈ sqrt(k/d) → sqrt(k) * z / sqrt(k)
    # ... the block radius itself fluctuates; sqrt(k)*z has unit variance-ish)
    return np.sqrt(k) * z


def lloyd_max_train(samples: np.ndarray, levels: int, iters: int = 200) -> np.ndarray:
    """Classic Lloyd iteration: alternate nearest-level partition and
    centroid update until convergence.  Returns sorted level array."""
    samples = np.sort(samples.astype(np.float64))
    lo, hi = samples[0], samples[-1]
    codebook = np.linspace(lo, hi, levels + 2)[1:-1]
    for _ in range(iters):
        bounds = (codebook[1:] + codebook[:-1]) / 2.0
        idx = np.searchsorted(bounds, samples)
        new = codebook.copy()
        for j in range(levels):
            sel = samples[idx == j]
            if sel.size:
                new[j] = sel.mean()
        if np.max(np.abs(new - codebook)) < 1e-10:
            codebook = new
            break
        codebook = new
    return codebook


_CODEBOOK_CACHE: dict[tuple[int, int], np.ndarray] = {}


def lloyd_max_codebook(k: int, bits: int) -> np.ndarray:
    """Trained codebook for (block size k, bit width b), cached."""
    key = (k, bits)
    if key not in _CODEBOOK_CACHE:
        _CODEBOOK_CACHE[key] = lloyd_max_train(marginal_samples(k), 2**bits)
    return _CODEBOOK_CACHE[key]


# --------------------------------------------------------------------------
# Gaussian codebooks (classic Lloyd–Max for N(0,1)) — used when the input
# is not normalized per-vector (ablation axis) and by the rust parity path.
# --------------------------------------------------------------------------

def gaussian_codebook(bits: int) -> np.ndarray:
    rng = np.random.default_rng(0)
    return lloyd_max_train(rng.standard_normal(400_000), 2**bits)


# --------------------------------------------------------------------------
# jnp quantize/dequantize (used inside kernels and by ref.py)
# --------------------------------------------------------------------------

def quantize_codebook(x, codebook):
    """Nearest-codeword index via boundary search (monotone codebook)."""
    cb = jnp.asarray(codebook, dtype=x.dtype)
    bounds = (cb[1:] + cb[:-1]) * jnp.asarray(0.5, dtype=x.dtype)
    # sum of (x > bound) over bounds — branch-free, Pallas-friendly.
    idx = jnp.sum(
        (x[..., None] > bounds).astype(jnp.int32), axis=-1, dtype=jnp.int32
    )
    return idx


def dequantize_codebook(idx, codebook, dtype):
    cb = jnp.asarray(codebook, dtype=dtype)
    return jnp.take(cb, idx, axis=0)


def quant_dequant_codebook(x, codebook):
    """Fused quantize→dequantize (the stage-1 Q of paper Alg. 1)."""
    return dequantize_codebook(quantize_codebook(x, codebook), codebook, x.dtype)


def uniform_clip(bits: int, k: int) -> float:
    """Clip range for the uniform quantizer: the support of the scaled
    marginal is [-sqrt(k), sqrt(k)]."""
    return math.sqrt(k)


def quant_dequant_uniform(x, bits: int, clip: float):
    """Symmetric mid-rise uniform quantizer on [-clip, clip]."""
    n = 2**bits
    step = 2.0 * clip / n
    xc = jnp.clip(x, -clip, clip - 1e-7 * clip)
    idx = jnp.floor((xc + clip) / step)
    idx = jnp.clip(idx, 0, n - 1)
    return (idx + 0.5) * step - clip


# --------------------------------------------------------------------------
# Norm / direction split (paper eq. 3)
# --------------------------------------------------------------------------

def norm_split(x, eps=1e-12):
    """x = rho * xbar with rho stored separately (paper eq. 3)."""
    rho = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    xbar = x / jnp.maximum(rho, eps)
    return rho, xbar
