"""Fused Pallas kernels for the IsoQuant stage-1 pipeline (L1).

One kernel per operating point (Full / Fast / 2D).  Each kernel fuses the
entire stage-1 path of paper Alg. 1 — norm split, blockwise rotation,
sqrt(d)-scaled scalar quantize→dequantize, inverse rotation, norm restore
— over a (TILE_B, d) tile of vectors resident in VMEM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA prototype
tiles threadblocks over (batch × blocks) with each 4-float block in
registers; here a grid step owns a (TILE_B, d) VMEM tile and the 4-wide
quaternion blocks are fixed linear recombinations of adjacent lanes
(reshape to (TILE_B, g, 4) is a no-op relayout in VMEM).  ``d`` being a
multiple of 4 means no masking anywhere — the paper's alignment argument.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same program runs
under the Rust runtime.  Real-TPU performance is estimated analytically
(DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import quaternion as quat
from .quantizer import lloyd_max_codebook, quant_dequant_uniform, uniform_clip

_EPS = 1e-12


def _tile_b(batch: int) -> int:
    """Largest power-of-two batch tile ≤ 128 dividing ``batch``."""
    t = 128
    while t > 1 and batch % t != 0:
        t //= 2
    return t


def _norm_split(x):
    rho = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return rho, x / jnp.maximum(rho, jnp.asarray(_EPS, x.dtype))


def _qdq(ys, codebook):
    """Branch-free codebook quantize→dequantize on VMEM values.

    ``codebook`` enters as python floats, so the boundary comparisons
    unroll into 2^b - 1 lane-wise compare+selects — exactly the fused
    form the paper's CUDA kernel uses.  Scalar constants only: Pallas
    kernels may not capture array constants."""
    cb = [float(c) for c in codebook]
    out = jnp.full(ys.shape, cb[0], dtype=ys.dtype)
    for j in range(len(cb) - 1):
        bound = 0.5 * (cb[j] + cb[j + 1])
        out = jnp.where(ys > bound, jnp.asarray(cb[j + 1], ys.dtype), out)
    return out


def _quant(y, d, k, bits, quantizer):
    s = jnp.asarray(np.sqrt(d), dtype=y.dtype)
    ys = y * s
    if quantizer == "lloyd":
        yq = _qdq(ys, np.asarray(lloyd_max_codebook(k, bits)))
    else:
        yq = quant_dequant_uniform(ys, bits, uniform_clip(bits, k))
    return yq / s


# --------------------------------------------------------------------------
# IsoQuant-Full
# --------------------------------------------------------------------------

def _full_kernel(x_ref, ql_ref, qr_ref, o_ref, *, d, bits, quantizer):
    x = x_ref[...]
    tb = x.shape[0]
    g = ql_ref.shape[0]
    rho, xbar = _norm_split(x)
    v = xbar.reshape(tb, g, 4)
    ql = ql_ref[...][None]
    qr = qr_ref[...][None]
    y = quat.sandwich(ql, v, qr)
    yq = _quant(y, d, 4, bits, quantizer)
    rec = quat.sandwich_inv(ql, yq, qr)
    o_ref[...] = rho * rec.reshape(tb, d)


def isoquant_full(x, q_l, q_r, bits: int, quantizer: str = "lloyd"):
    """Fused stage-1 IsoQuant-Full over x (B, d); d must be divisible by 4
    (power-of-two head dims always are — the paper's alignment claim)."""
    b, d = x.shape
    assert d % 4 == 0, "IsoQuant 4D kernels require d % 4 == 0"
    tb = _tile_b(b)
    g = d // 4
    kern = functools.partial(_full_kernel, d=d, bits=bits, quantizer=quantizer)
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((g, 4), lambda i: (0, 0)),
            pl.BlockSpec((g, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, q_l.astype(x.dtype), q_r.astype(x.dtype))


# --------------------------------------------------------------------------
# IsoQuant-Fast
# --------------------------------------------------------------------------

def _fast_kernel(x_ref, ql_ref, o_ref, *, d, bits, quantizer):
    x = x_ref[...]
    tb = x.shape[0]
    g = ql_ref.shape[0]
    rho, xbar = _norm_split(x)
    v = xbar.reshape(tb, g, 4)
    ql = ql_ref[...][None]
    y = quat.left_mul(ql, v)
    yq = _quant(y, d, 4, bits, quantizer)
    rec = quat.left_mul_inv(ql, yq)
    o_ref[...] = rho * rec.reshape(tb, d)


def isoquant_fast(x, q_l, bits: int, quantizer: str = "lloyd"):
    """Fused stage-1 IsoQuant-Fast (single isoclinic factor)."""
    b, d = x.shape
    assert d % 4 == 0
    tb = _tile_b(b)
    g = d // 4
    kern = functools.partial(_fast_kernel, d=d, bits=bits, quantizer=quantizer)
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((g, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, q_l.astype(x.dtype))


# --------------------------------------------------------------------------
# IsoQuant-2D (planar special case)
# --------------------------------------------------------------------------

def _planar_kernel(x_ref, cs_ref, o_ref, *, d, bits, quantizer):
    x = x_ref[...]
    tb = x.shape[0]
    g = cs_ref.shape[0]
    rho, xbar = _norm_split(x)
    u = xbar.reshape(tb, g, 2)
    c = cs_ref[...][None, :, 0]
    s = cs_ref[...][None, :, 1]
    u0, u1 = u[..., 0], u[..., 1]
    y = jnp.stack([c * u0 - s * u1, s * u0 + c * u1], axis=-1)
    yq = _quant(y, d, 2, bits, quantizer)
    y0, y1 = yq[..., 0], yq[..., 1]
    rec = jnp.stack([c * y0 + s * y1, -s * y0 + c * y1], axis=-1)
    o_ref[...] = rho * rec.reshape(tb, d)


def isoquant_2d(x, theta, bits: int, quantizer: str = "lloyd"):
    """Fused stage-1 planar special case; d must be even.

    cos/sin are precomputed once outside the kernel (they are parameters,
    not activations) and passed as a (g, 2) bank — mirroring the CUDA
    prototype, which stores the rotation as (cos θ, sin θ) pairs."""
    b, d = x.shape
    assert d % 2 == 0
    tb = _tile_b(b)
    g = d // 2
    cs = jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=-1).astype(x.dtype)
    kern = functools.partial(_planar_kernel, d=d, bits=bits, quantizer=quantizer)
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((g, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, cs)
