"""Pure-jnp oracle for every stage-1 pipeline (the correctness ground truth).

Each function maps a batch ``x (B, d)`` plus rotation parameters to the
stage-1 reconstruction ``xhat (B, d)`` following paper Alg. 1:

    1.  rho, xbar = norm_split(x)                       (eq. 3)
    2.  y  = blockwise_rotate(xbar)                     (eq. 22/25/29)
    3.  yq = Q(sqrt(d) * y) / sqrt(d)                   (scalar quantizer)
    4.  xrec_bar = blockwise_rotate_inverse(yq)         (eq. 24/27/31)
    5.  xhat = rho * xrec_bar

The sqrt(d) pre-scale makes one trained codebook serve every d: a
normalized d-vector has coordinates at scale ~1/sqrt(d), and the
Lloyd–Max codebooks in ``quantizer.py`` are trained on the sqrt(d)-scaled
marginal (unit block radius × sqrt(k)).

The Pallas kernels in ``isoquant.py`` / ``rotor3d.py`` / ``dense_rot.py``
must match these functions to float tolerance — that is what
``python/tests/test_kernels_vs_ref.py`` asserts — and the Rust native
path (rust/src/quant/pipeline.rs) must match the AOT-lowered HLO of
these same graphs (cross-language parity test).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import quaternion as quat
from .quantizer import (
    lloyd_max_codebook,
    norm_split,
    quant_dequant_codebook,
    quant_dequant_uniform,
    uniform_clip,
)


def _pad_to(x, width: int):
    """Zero-pad the trailing feature axis to ``width`` (paper §5.1)."""
    d = x.shape[-1]
    if d == width:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, width - d)]
    return jnp.pad(x, pad)


def _quant(y, d: int, k: int, bits: int, quantizer: str):
    """sqrt(d)-scaled scalar quantize→dequantize."""
    s = jnp.asarray(np.sqrt(d), dtype=y.dtype)
    ys = y * s
    if quantizer == "lloyd":
        yq = quant_dequant_codebook(ys, lloyd_max_codebook(k, bits))
    elif quantizer == "uniform":
        yq = quant_dequant_uniform(ys, bits, uniform_clip(bits, k))
    else:
        raise ValueError(f"unknown quantizer {quantizer!r}")
    return yq / s


# --------------------------------------------------------------------------
# IsoQuant-Full (paper §5.2): v -> qL v conj(qR), full SO(4)
# --------------------------------------------------------------------------

def isoquant_full(x, q_l, q_r, bits: int, quantizer: str = "lloyd"):
    b, d = x.shape
    g = q_l.shape[0]
    rho, xbar = norm_split(x)
    v = _pad_to(xbar, 4 * g).reshape(b, g, 4)
    ql = q_l.astype(x.dtype)[None]          # (1, g, 4), broadcast over batch
    qr = q_r.astype(x.dtype)[None]
    y = quat.sandwich(ql, v, qr)            # eq. 22
    yq = _quant(y, d, 4, bits, quantizer)   # eq. 23
    rec = quat.sandwich_inv(ql, yq, qr)     # eq. 24
    return rho * rec.reshape(b, 4 * g)[:, :d]


# --------------------------------------------------------------------------
# IsoQuant-Fast (paper §5.3): v -> qL v, single isoclinic factor
# --------------------------------------------------------------------------

def isoquant_fast(x, q_l, bits: int, quantizer: str = "lloyd"):
    b, d = x.shape
    g = q_l.shape[0]
    rho, xbar = norm_split(x)
    v = _pad_to(xbar, 4 * g).reshape(b, g, 4)
    ql = q_l.astype(x.dtype)[None]
    y = quat.left_mul(ql, v)                # eq. 25
    yq = _quant(y, d, 4, bits, quantizer)
    rec = quat.left_mul_inv(ql, yq)         # eq. 27
    return rho * rec.reshape(b, 4 * g)[:, :d]


# --------------------------------------------------------------------------
# IsoQuant-2D (paper §5.4): planar Givens rotations on coordinate pairs
# --------------------------------------------------------------------------

def isoquant_2d(x, theta, bits: int, quantizer: str = "lloyd"):
    b, d = x.shape
    g = theta.shape[0]
    rho, xbar = norm_split(x)
    u = _pad_to(xbar, 2 * g).reshape(b, g, 2)
    c = jnp.cos(theta).astype(x.dtype)[None]    # (1, g)
    s = jnp.sin(theta).astype(x.dtype)[None]
    u0, u1 = u[..., 0], u[..., 1]
    y = jnp.stack([c * u0 - s * u1, s * u0 + c * u1], axis=-1)  # eq. 29
    yq = _quant(y, d, 2, bits, quantizer)
    y0, y1 = yq[..., 0], yq[..., 1]
    rec = jnp.stack([c * y0 + s * y1, -s * y0 + c * y1], axis=-1)  # eq. 31
    return rho * rec.reshape(b, 2 * g)[:, :d]


# --------------------------------------------------------------------------
# RotorQuant baseline (paper [2]): 3D Clifford rotor blocks + 2D tail
# --------------------------------------------------------------------------

def _rotate3(q, v3):
    """Rotate 3-vectors by the rotor encoded in unit quaternion q:
    v -> q v conj(q) restricted to the pure part.  This is the
    odd-intermediate form of the Cl(3,0) sandwich R v R~."""
    v = jnp.concatenate([jnp.zeros_like(v3[..., :1]), v3], axis=-1)
    out = quat.hamilton(quat.hamilton(q, v), quat.conjugate(q))
    return out[..., 1:]


def _rotate3_inv(q, v3):
    v = jnp.concatenate([jnp.zeros_like(v3[..., :1]), v3], axis=-1)
    out = quat.hamilton(quat.hamilton(quat.conjugate(q), v), q)
    return out[..., 1:]


def rotorquant(x, q, tail_theta, bits: int, quantizer: str = "lloyd"):
    """RotorQuant stage-1: floor(d/3) rotor blocks plus a planar tail.

    At d = 128: 42 full 3D blocks + one 2D tail (§1).  The quantizer uses
    the k=3 marginal codebook for the blocks and k=2 for the tail, both
    at the same bit width — matching the blockwise structure."""
    b, d = x.shape
    nfull = q.shape[0]
    rho, xbar = norm_split(x)
    body = xbar[:, : 3 * nfull].reshape(b, nfull, 3)
    qb = q.astype(x.dtype)[None]
    y = _rotate3(qb, body)
    yq = _quant(y, d, 3, bits, quantizer)
    rec = _rotate3_inv(qb, yq).reshape(b, 3 * nfull)

    tail = xbar[:, 3 * nfull :]
    tw = tail.shape[-1]
    if tw == 2:
        c = jnp.cos(tail_theta).astype(x.dtype)
        s = jnp.sin(tail_theta).astype(x.dtype)
        t0, t1 = tail[..., 0], tail[..., 1]
        ty = jnp.stack([c * t0 - s * t1, s * t0 + c * t1], axis=-1)
        tyq = _quant(ty, d, 2, bits, quantizer)
        ty0, ty1 = tyq[..., 0], tyq[..., 1]
        tail_rec = jnp.stack([c * ty0 + s * ty1, -s * ty0 + c * ty1], axis=-1)
    elif tw == 1:
        tail_rec = _quant(tail, d, 2, bits, quantizer)
    else:
        tail_rec = tail
    return rho * jnp.concatenate([rec, tail_rec], axis=-1)


# --------------------------------------------------------------------------
# TurboQuant-style dense rotation baseline (paper [1], Table 1 row 1)
# --------------------------------------------------------------------------

def dense_rotation(x, mat, bits: int, quantizer: str = "lloyd"):
    """Dense d x d orthogonal rotation + scalar quantization.  Used as the
    conceptual dense reference in the complexity analysis (§9.1)."""
    b, d = x.shape
    rho, xbar = norm_split(x)
    m = mat.astype(x.dtype)
    y = xbar @ m.T
    # a dense Haar rotation mixes globally; the per-coordinate marginal is
    # that of a d-sphere coordinate — approximately Gaussian for large d —
    # the k=4 codebook (semicircle-like, near-Gaussian) is the best match
    # among the trained tables at the same sqrt(d) scale.
    yq = _quant(y, d, 4, bits, quantizer)
    rec = yq @ m
    return rho * rec


# --------------------------------------------------------------------------
# Identity baseline (no rotation) — isolates the value of decorrelation
# --------------------------------------------------------------------------

def identity(x, bits: int, quantizer: str = "lloyd"):
    b, d = x.shape
    rho, xbar = norm_split(x)
    yq = _quant(xbar, d, 4, bits, quantizer)
    return rho * yq


def mse(x, xhat):
    return jnp.mean((x - xhat) ** 2)
