"""Fused Pallas kernel for the TurboQuant-style dense rotation baseline.

The dense orthogonal transform is the conceptual upper bound in the
paper's Table 1 (16,384 FMAs at d=128 vs 1,024 for IsoQuant-Full).  On
TPU this is the one variant where the MXU actually wins: the rotation is
a (TILE_B, d) × (d, d) matmul feeding the systolic array, while the
blockwise variants are VPU lane recombinations.  We therefore express the
rotation with ``jnp.dot`` inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .isoquant import _norm_split, _quant, _tile_b


def _dense_kernel(x_ref, m_ref, o_ref, *, d, bits, quantizer):
    x = x_ref[...]
    rho, xbar = _norm_split(x)
    m = m_ref[...]
    y = jnp.dot(xbar, m.T)
    yq = _quant(y, d, 4, bits, quantizer)
    rec = jnp.dot(yq, m)
    o_ref[...] = rho * rec


def dense_rotation(x, mat, bits: int, quantizer: str = "lloyd"):
    """Fused dense-rotation stage-1 over x (B, d), mat (d, d) orthogonal."""
    b, d = x.shape
    tb = _tile_b(b)
    kern = functools.partial(_dense_kernel, d=d, bits=bits, quantizer=quantizer)
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, mat.astype(x.dtype))
