"""Rotation parameter banks (random, Haar-distributed) for every variant.

The paper's lightweight instantiation samples the unconstrained vectors
``u`` from a Gaussian and normalizes (§5.5) — Gaussian-normalize sampling
is exactly Haar on S^3, and uniform angles are Haar on SO(2).  The same
seeds/derivations are mirrored in ``rust/src/quant/params.rs``; parity
between the two is established by exporting the banks into the AOT
manifest rather than re-deriving them (PRNGs differ across languages).
"""

from __future__ import annotations

import numpy as np


def g4(d: int) -> int:
    """Number of 4D blocks, ceil(d/4) (paper eq. 14/19)."""
    return (d + 3) // 4


def g2(d: int) -> int:
    """Number of 2D blocks for the planar special case."""
    return (d + 1) // 2


def g3(d: int) -> tuple[int, int]:
    """RotorQuant partition: (full 3D blocks, tail width in {0,1,2})."""
    return d // 3, d % 3


def haar_s3(rng: np.random.Generator, n: int) -> np.ndarray:
    """n Haar-uniform unit quaternions, shape (n, 4)."""
    u = rng.standard_normal((n, 4))
    return u / np.linalg.norm(u, axis=-1, keepdims=True)


def quaternion_pairs(d: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """(qL, qR) banks for IsoQuant-Full, each (g4, 4)."""
    rng = np.random.default_rng(seed)
    g = g4(d)
    return haar_s3(rng, g), haar_s3(rng, g)


def quaternion_single(d: int, seed: int) -> np.ndarray:
    """qL bank for IsoQuant-Fast, (g4, 4)."""
    rng = np.random.default_rng(seed)
    return haar_s3(rng, g4(d))


def planar_angles(d: int, seed: int) -> np.ndarray:
    """theta bank for the 2D special case, (g2,), Haar = Unif[0, 2pi)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 2.0 * np.pi, size=g2(d))


def rotor3_params(d: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """RotorQuant baseline parameters.

    Returns (q, tail_theta): ``q`` is a (g3, 4) bank of unit quaternions —
    each encodes a Cl(3,0) rotor R = cos(a/2) + sin(a/2) B acting on a 3D
    block — plus a single planar angle for the 2-wide tail (d mod 3 == 2,
    e.g. d = 128 → 42 blocks + 2D tail, §1).  A 1-wide tail (d mod 3 == 1)
    has no rotational freedom and passes through.
    """
    rng = np.random.default_rng(seed)
    nfull, tail = g3(d)
    q = haar_s3(rng, nfull)
    theta = rng.uniform(0.0, 2.0 * np.pi, size=1 if tail == 2 else 0)
    return q, theta


def dense_orthogonal(d: int, seed: int) -> np.ndarray:
    """Haar-distributed dense d x d orthogonal matrix (TurboQuant
    reference): QR of a Gaussian with sign-fixed R diagonal."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, d))
    q, r = np.linalg.qr(a)
    return q * np.sign(np.diag(r))
