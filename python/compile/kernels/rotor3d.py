"""Fused Pallas kernel for the RotorQuant baseline (3D Clifford rotors).

This is the *baseline* the paper compares against, implemented with the
same fused treatment as the IsoQuant kernels so that the comparison is
apples-to-apples (§9.1: "RotorQuant and IsoQuant are benchmarked under
the same tensor shape, bit width, and execution dtype").

The structural disadvantages the paper attributes to 3D blocking are
visible directly in this kernel:

* ``d`` is never divisible by 3 for power-of-two head dims, so the tile
  splits into a (TILE_B, 3·g3) body plus a ragged 1- or 2-wide tail with
  its own code path (d=128 → 42 blocks + 2D tail);
* the rotor sandwich needs two Hamilton products on zero-padded 4-wide
  intermediates (the Cl(3,0) even/odd multivector expansion), costing
  more FMAs per covered coordinate than the 4D isoclinic form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quaternion as quat
from .isoquant import _norm_split, _quant, _tile_b


def _rotor_kernel(x_ref, q_ref, cs_ref, o_ref, *, d, bits, quantizer, nfull, tail):
    x = x_ref[...]
    tb = x.shape[0]
    rho, xbar = _norm_split(x)

    body = xbar[:, : 3 * nfull].reshape(tb, nfull, 3)
    q = q_ref[...][None]
    # Cl(3,0) sandwich R v R~ in the odd-intermediate quaternion form:
    # embed the 3-vector as a pure quaternion, two Hamilton products.
    zeros = jnp.zeros((tb, nfull, 1), dtype=x.dtype)
    v = jnp.concatenate([zeros, body], axis=-1)
    y = quat.hamilton(quat.hamilton(q, v), quat.conjugate(q))[..., 1:]
    yq = _quant(y, d, 3, bits, quantizer)
    vq = jnp.concatenate([zeros, yq], axis=-1)
    rec = quat.hamilton(quat.hamilton(quat.conjugate(q), vq), q)[..., 1:]
    rec = rec.reshape(tb, 3 * nfull)

    if tail == 2:
        t = xbar[:, 3 * nfull :]
        c = cs_ref[0, 0]
        s = cs_ref[0, 1]
        t0, t1 = t[..., 0], t[..., 1]
        ty = jnp.stack([c * t0 - s * t1, s * t0 + c * t1], axis=-1)
        tyq = _quant(ty, d, 2, bits, quantizer)
        ty0, ty1 = tyq[..., 0], tyq[..., 1]
        trec = jnp.stack([c * ty0 + s * ty1, -s * ty0 + c * ty1], axis=-1)
        out = jnp.concatenate([rec, trec], axis=-1)
    elif tail == 1:
        t = xbar[:, 3 * nfull :]
        trec = _quant(t, d, 2, bits, quantizer)
        out = jnp.concatenate([rec, trec], axis=-1)
    else:
        out = rec
    o_ref[...] = rho * out


def rotorquant(x, q, tail_theta, bits: int, quantizer: str = "lloyd"):
    """Fused RotorQuant stage-1 over x (B, d): floor(d/3) rotor blocks plus
    the planar tail, matching ``ref.rotorquant``."""
    b, d = x.shape
    nfull, tail = d // 3, d % 3
    assert q.shape[0] == nfull
    tb = _tile_b(b)
    # (1, 2) cos/sin bank for the tail; a dummy when there is no 2D tail so
    # the kernel signature stays uniform.
    if tail == 2:
        cs = jnp.stack([jnp.cos(tail_theta), jnp.sin(tail_theta)], axis=-1)
        cs = cs.reshape(1, 2).astype(x.dtype)
    else:
        cs = jnp.zeros((1, 2), dtype=x.dtype)
    kern = functools.partial(
        _rotor_kernel, d=d, bits=bits, quantizer=quantizer, nfull=nfull, tail=tail
    )
    return pl.pallas_call(
        kern,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((nfull, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, q.astype(x.dtype), cs)
