"""Quaternion algebra helpers (build-time, jnp).

Quaternions are stored as arrays whose last axis has size 4, ordered
``(w, x, y, z)`` = ``w + x i + y j + z k``.  All functions broadcast over
leading axes, so a bank of per-block quaternions ``(g, 4)`` applied to a
batch of blocks ``(B, g, 4)`` works without reshaping.

These helpers are the shared algebra layer used by

* the pure-jnp reference oracle (``ref.py``), and
* the fused Pallas kernels (``isoquant.py``), which call them on values
  already resident in the kernel's VMEM refs.
"""

from __future__ import annotations

import jax.numpy as jnp


def hamilton(a, b):
    """Hamilton product ``a * b`` of quaternion arrays ``(..., 4)``.

    16 multiplies / 12 adds per product — the unit the paper counts as
    ~16 FMAs (§6).
    """
    aw, ax, ay, az = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    bw, bx, by, bz = b[..., 0], b[..., 1], b[..., 2], b[..., 3]
    return jnp.stack(
        [
            aw * bw - ax * bx - ay * by - az * bz,
            aw * bx + ax * bw + ay * bz - az * by,
            aw * by - ax * bz + ay * bw + az * bx,
            aw * bz + ax * by - ay * bx + az * bw,
        ],
        axis=-1,
    )


def conjugate(q):
    """Quaternion conjugate ``w - xi - yj - zk``.

    Written as a stack of negations (not a multiply by a constant sign
    vector) so it stays Pallas-legal: kernels may not capture array
    constants, only scalars."""
    return jnp.stack(
        [q[..., 0], -q[..., 1], -q[..., 2], -q[..., 3]], axis=-1
    )


def normalize(u, eps=1e-12):
    """Project onto the unit sphere S^3 (paper eq. 33)."""
    n = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    return u / jnp.maximum(n, eps)


def sandwich(q_l, v, q_r):
    """Double-sided isoclinic action ``T(v) = q_l · v · conj(q_r)``
    (paper eq. 11): the general element of SO(4)."""
    return hamilton(hamilton(q_l, v), conjugate(q_r))


def sandwich_inv(q_l, v, q_r):
    """Inverse action ``conj(q_l) · v · q_r`` (paper eq. 12)."""
    return hamilton(hamilton(conjugate(q_l), v), q_r)


def left_mul(q_l, v):
    """Single left-isoclinic factor (IsoQuant-Fast forward, eq. 25)."""
    return hamilton(q_l, v)


def left_mul_inv(q_l, v):
    """IsoQuant-Fast inverse (eq. 27)."""
    return hamilton(conjugate(q_l), v)


def so4_matrix(q_l, q_r):
    """Materialize the 4x4 rotation matrix of ``v -> q_l v conj(q_r)``.

    Only used by tests to verify orthogonality / determinant; the
    kernels never build this matrix (that is the point of the paper).
    """
    cols = []
    eye = jnp.eye(4, dtype=q_l.dtype)
    for i in range(4):
        cols.append(sandwich(q_l, eye[i], q_r))
    # stack(..., axis=-1)[j, i] = T(e_i)_j: column i is the image of e_i,
    # so out = M @ v.
    return jnp.stack(cols, axis=-1)
