"""AOT compile path: lower every L2 graph to HLO text + write the manifest.

Run via ``make artifacts`` (no-op when inputs are unchanged).  Python runs
ONLY here; the Rust binary is self-contained once ``artifacts/`` exists.

Interchange format is HLO **text**, not serialized HloModuleProto: the
runtime links xla_extension 0.5.1, which rejects the 64-bit instruction
ids jax ≥ 0.5 emits in protos (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  *.hlo.txt        — one per graph
  manifest.json    — graph inventory: inputs/outputs (name, shape, dtype),
                     model config, weight specs
  weights.bin      — deterministic model weights (tensorfile format, see
                     rust/src/util/tensorfile.rs)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import params as kparams

DTYPE_NAMES = {jnp.float32: "f32", jnp.float16: "f16", jnp.int32: "i32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_dict(name, s):
    dt = {np.dtype(np.float32): "f32", np.dtype(np.float16): "f16",
          np.dtype(np.int32): "i32"}[np.dtype(s.dtype)]
    return {"name": name, "shape": list(s.shape), "dtype": dt}


def lower_graph(fn, example_args, arg_names):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    inputs = [_spec_dict(n, s) for n, s in zip(arg_names, example_args)]
    return text, inputs


# --------------------------------------------------------------------------
# tensorfile: the weights.bin format shared with rust/src/util/tensorfile.rs
#   magic "ISOQTNSR" | u32 version | u32 count
#   per tensor: u32 name_len | name utf8 | u32 ndim | u64 dims[] |
#               u32 dtype(0=f32,1=f16,2=i32) | u64 byte_len | raw bytes
# --------------------------------------------------------------------------

def write_tensorfile(path: str, tensors: list[tuple[str, np.ndarray]]):
    with open(path, "wb") as f:
        f.write(b"ISOQTNSR")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<Q", dim))
            code = {np.dtype(np.float32): 0, np.dtype(np.float16): 1,
                    np.dtype(np.int32): 2}[arr.dtype]
            raw = np.ascontiguousarray(arr).tobytes()
            f.write(struct.pack("<IQ", code, len(raw)))
            f.write(raw)


# --------------------------------------------------------------------------
# Artifact inventory
# --------------------------------------------------------------------------

# Stage-1 parity graphs: fixed batch, f32, lloyd quantizer.  These anchor
# the Rust native pipeline to the Pallas/HLO semantics.
PARITY_BATCH = 64
PARITY_CONFIGS = [
    ("full", 128, 2), ("full", 128, 4), ("full", 64, 3),
    ("fast", 128, 2), ("fast", 128, 4),
    ("2d", 128, 2), ("2d", 128, 4),
    ("rotor", 128, 2), ("rotor", 128, 4),
    ("dense", 128, 4),
]

STAGE1_ARG_NAMES = {
    "full": ["x", "q_l", "q_r"],
    "fast": ["x", "q_l"],
    "2d": ["x", "theta"],
    "rotor": ["x", "q", "tail_theta"],
    "dense": ["x", "m"],
}

SERVE_BATCH = 4
ATT_T = 128


def build_all(out_dir: str, serve_batch: int = SERVE_BATCH) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    cfg = M.ModelConfig()
    manifest = {
        "version": 1,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq, "prefill_chunk": cfg.prefill_chunk,
            "n_params": cfg.n_params(), "serve_batch": serve_batch,
        },
        "weights": "weights.bin",
        "weight_specs": [
            {"name": n, "shape": list(s)} for n, s in cfg.weight_specs
        ],
        "artifacts": [],
    }

    def emit(name, fn, example_args, arg_names, meta=None):
        text, inputs = lower_graph(fn, example_args, arg_names)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": inputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if meta:
            entry["meta"] = meta
        manifest["artifacts"].append(entry)
        print(f"  {fname}: {len(text)} chars, {len(inputs)} inputs")

    # 1. stage-1 parity graphs
    for variant, d, bits in PARITY_CONFIGS:
        fn = M.stage1_graph(variant, bits)
        args = M.stage1_example_args(variant, PARITY_BATCH, d)
        emit(
            f"stage1_{variant}_d{d}_b{bits}",
            fn, args, STAGE1_ARG_NAMES[variant],
            meta={"kind": "stage1", "variant": variant, "d": d, "bits": bits,
                  "batch": PARITY_BATCH, "quantizer": "lloyd"},
        )

    # 2. serving graphs
    wnames = [n for n, _ in cfg.weight_specs]
    emit(
        "decode_step",
        M.decode_step(cfg),
        M.decode_example_args(cfg, serve_batch),
        ["tok", "pos", "k_cache", "v_cache"] + wnames,
        meta={"kind": "decode", "batch": serve_batch},
    )
    emit(
        "prefill_chunk",
        M.prefill_chunk(cfg),
        M.prefill_example_args(cfg, serve_batch),
        ["tok", "pos0", "k_cache", "v_cache"] + wnames,
        meta={"kind": "prefill", "batch": serve_batch, "chunk": cfg.prefill_chunk},
    )

    # 3. attention scorer for fidelity experiments
    emit(
        "attention_scorer",
        M.attention_scorer(cfg.d_head),
        M.attention_example_args(serve_batch, cfg.n_heads, ATT_T, cfg.d_head),
        ["q", "k", "v"],
        meta={"kind": "attention", "batch": serve_batch, "t": ATT_T},
    )

    # weights
    weights = M.init_weights(cfg, seed=0)
    write_tensorfile(
        os.path.join(out_dir, "weights.bin"),
        list(zip(wnames, weights)),
    )
    print(f"  weights.bin: {cfg.n_params()} params")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="(legacy) single-file mode ignored")
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts"))
    ap.add_argument("--serve-batch", type=int, default=SERVE_BATCH)
    args = ap.parse_args()
    print(f"lowering artifacts into {args.out_dir}")
    build_all(args.out_dir, args.serve_batch)
    print("done")


if __name__ == "__main__":
    main()
