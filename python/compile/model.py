"""L2: JAX compute graphs that get AOT-lowered into the Rust runtime.

Three families of graphs:

1. **Stage-1 parity graphs** — the fused quantize→dequantize pipelines
   from ``kernels/`` wrapped at fixed shapes.  The Rust native pipeline
   (rust/src/quant/pipeline.rs) is cross-checked against the lowered HLO
   of these graphs at runtime (``isoquant selfcheck``) and in the
   integration tests — the cross-language correctness anchor.

2. **Transformer serving graphs** — a small decoder-only transformer
   (the E2E serving model): a chunked prefill step and a single-token
   decode step.  KV caches are *inputs*: at serve time the Rust
   coordinator stores them compressed (IsoQuant pages) and reconstructs
   the dense tensors it feeds the step — the paper's deployment story
   (compressed KV cache + cheap stage-1 transform on the critical path).

3. **Attention scorer** — isolated attention-logit computation used by
   the fidelity experiments (§9.6 directions).

Weights are runtime *inputs* (not baked constants) so artifacts stay
small and the Rust side can own weight initialization; the exact shapes
are recorded in the manifest.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dense_rot, isoquant, params as kparams, rotor3d


# --------------------------------------------------------------------------
# 1. Stage-1 parity graphs
# --------------------------------------------------------------------------

def stage1_graph(variant: str, bits: int, quantizer: str = "lloyd"):
    """Returns f(x, *params) -> (xhat,) for the given variant."""
    if variant == "full":
        def f(x, ql, qr):
            return (isoquant.isoquant_full(x, ql, qr, bits, quantizer),)
    elif variant == "fast":
        def f(x, ql):
            return (isoquant.isoquant_fast(x, ql, bits, quantizer),)
    elif variant == "2d":
        def f(x, theta):
            return (isoquant.isoquant_2d(x, theta, bits, quantizer),)
    elif variant == "rotor":
        def f(x, q, tail):
            return (rotor3d.rotorquant(x, q, tail, bits, quantizer),)
    elif variant == "dense":
        def f(x, m):
            return (dense_rot.dense_rotation(x, m, bits, quantizer),)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return f


def stage1_example_args(variant: str, batch: int, d: int, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering + the parameter bank shapes."""
    x = jax.ShapeDtypeStruct((batch, d), dtype)
    if variant == "full":
        g = kparams.g4(d)
        return [x, jax.ShapeDtypeStruct((g, 4), dtype), jax.ShapeDtypeStruct((g, 4), dtype)]
    if variant == "fast":
        g = kparams.g4(d)
        return [x, jax.ShapeDtypeStruct((g, 4), dtype)]
    if variant == "2d":
        g = kparams.g2(d)
        return [x, jax.ShapeDtypeStruct((g,), dtype)]
    if variant == "rotor":
        nfull, tail = kparams.g3(d)
        return [
            x,
            jax.ShapeDtypeStruct((nfull, 4), dtype),
            jax.ShapeDtypeStruct((1 if tail == 2 else 0,), dtype),
        ]
    if variant == "dense":
        return [x, jax.ShapeDtypeStruct((d, d), dtype)]
    raise ValueError(variant)


# --------------------------------------------------------------------------
# 2. Transformer serving graphs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Small decoder-only transformer used by the E2E serving example."""
    vocab: int = 512
    d_model: int = 256
    n_heads: int = 4
    d_head: int = 64          # == paper's primary head width
    n_layers: int = 2
    d_ff: int = 512
    max_seq: int = 256
    prefill_chunk: int = 32

    @property
    def weight_specs(self) -> list[tuple[str, tuple[int, ...]]]:
        """Flat, ordered weight list — the manifest/rust contract."""
        specs: list[tuple[str, tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model)),
        ]
        for l in range(self.n_layers):
            p = f"layer{l}."
            specs += [
                (p + "ln1_g", (self.d_model,)),
                (p + "wq", (self.d_model, self.d_model)),
                (p + "wk", (self.d_model, self.d_model)),
                (p + "wv", (self.d_model, self.d_model)),
                (p + "wo", (self.d_model, self.d_model)),
                (p + "ln2_g", (self.d_model,)),
                (p + "w1", (self.d_model, self.d_ff)),
                (p + "w2", (self.d_ff, self.d_model)),
            ]
        specs += [("ln_f_g", (self.d_model,)), ("unembed", (self.d_model, self.vocab))]
        return specs

    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.weight_specs)


def init_weights(cfg: ModelConfig, seed: int = 0) -> list[np.ndarray]:
    """Deterministic Gaussian init, scaled 1/sqrt(fan_in); layernorm gains 1.
    Mirrored in rust/src/runtime/weights.rs via the weights.bin file."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in cfg.weight_specs:
        if name.endswith("_g"):
            out.append(np.ones(shape, dtype=np.float32))
        else:
            fan_in = shape[0]
            out.append(
                (rng.standard_normal(shape) / math.sqrt(fan_in)).astype(np.float32)
            )
    return out


def _unflatten(cfg: ModelConfig, flat):
    w = {}
    for (name, _), arr in zip(cfg.weight_specs, flat):
        w[name] = arr
    return w


def _rmsnorm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _split_heads(x, cfg: ModelConfig):
    b = x.shape[0]
    return x.reshape(b, -1, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _rope(x, pos):
    """Rotary position embedding over the head dim (pairs of lanes).

    ``pos`` broadcasts over (B, H, T): pass an (T,) or scalar array."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None] * freqs  # (..., half)
    c, s = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(x.dtype)


def decode_step(cfg: ModelConfig):
    """Single-token decode step with per-lane positions (continuous
    batching: every batch lane may be at a different sequence position).

    Inputs:
      tok      (B,)  int32           — current token ids
      pos      (B,)  int32           — per-lane position (0-based)
      k_cache  (L, B, H, T, dh) f32  — reconstructed (decompressed) K cache
      v_cache  (L, B, H, T, dh) f32
      *weights                        — cfg.weight_specs order
    Outputs:
      logits   (B, vocab)
      k_new    (L, B, H, dh)          — this token's K per layer (rust
      v_new    (L, B, H, dh)            compresses and appends them)
    """
    L, H, T, DH = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head

    def f(tok, pos, k_cache, v_cache, *flat_w):
        w = _unflatten(cfg, flat_w)
        x = jnp.take(w["embed"], tok, axis=0)  # (B, dm)
        b = x.shape[0]
        k_news, v_news = [], []
        posf = pos.astype(jnp.float32)[:, None]  # (B, 1) broadcasting over H
        # causal validity mask over cache slots: lane b's slot t valid iff
        # t < pos[b]
        slot = jnp.arange(T)
        neg = jnp.asarray(-1e9, jnp.float32)
        mask = jnp.where(slot[None, :] < pos[:, None], 0.0, neg)[:, None, :]
        for l in range(cfg.n_layers):
            p = f"layer{l}."
            h = _rmsnorm(x, w[p + "ln1_g"])
            q = _split_heads(h @ w[p + "wq"], cfg)[:, :, 0, :]  # (B,H,dh)
            k = _split_heads(h @ w[p + "wk"], cfg)[:, :, 0, :]
            v = _split_heads(h @ w[p + "wv"], cfg)[:, :, 0, :]
            q = _rope(q, posf)
            k = _rope(k, posf)
            # attend over [cached 0..pos-1] ∪ [self]
            kc, vc = k_cache[l], v_cache[l]           # (B,H,T,dh)
            logits_c = jnp.einsum("bhd,bhtd->bht", q, kc) / math.sqrt(DH)
            logits_c = logits_c + mask
            logit_self = jnp.einsum("bhd,bhd->bh", q, k)[..., None] / math.sqrt(DH)
            all_logits = jnp.concatenate([logits_c, logit_self], axis=-1)
            att = jax.nn.softmax(all_logits, axis=-1)
            ctx = jnp.einsum("bht,bhtd->bhd", att[..., :T], vc) + att[..., T:] * v
            ctx = ctx.reshape(b, H * DH)
            x = x + ctx @ w[p + "wo"]
            h2 = _rmsnorm(x, w[p + "ln2_g"])
            x = x + (jax.nn.silu(h2 @ w[p + "w1"]) @ w[p + "w2"])
            k_news.append(k)
            v_news.append(v)
        x = _rmsnorm(x, w["ln_f_g"])
        logits = x @ w["unembed"]
        return (logits, jnp.stack(k_news), jnp.stack(v_news))

    return f


def decode_example_args(cfg: ModelConfig, batch: int):
    L, H, T, DH = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head
    args = [
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((L, batch, H, T, DH), jnp.float32),
        jax.ShapeDtypeStruct((L, batch, H, T, DH), jnp.float32),
    ]
    args += [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.weight_specs]
    return args


def prefill_chunk(cfg: ModelConfig):
    """Chunked prefill over P = cfg.prefill_chunk tokens with per-lane
    start positions (lanes may prefill different sequences / chunks).

    Inputs:
      tok      (B, P) int32
      pos0     (B,)   int32          — per-lane chunk start position
      k_cache / v_cache (L,B,H,T,dh) — previously prefilled (reconstructed)
      *weights
    Outputs:
      logits  (B, P, vocab)          — logits at every chunk position (the
                                       coordinator picks the last real one)
      k_chunk (L,B,H,P,dh), v_chunk  — this chunk's K/V (rust compresses)
    """
    P, L, H, T, DH = cfg.prefill_chunk, cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head

    def f(tok, pos0, k_cache, v_cache, *flat_w):
        w = _unflatten(cfg, flat_w)
        x = jnp.take(w["embed"], tok, axis=0)  # (B, P, dm)
        b = x.shape[0]
        # (B, P) absolute positions
        pos = pos0.astype(jnp.float32)[:, None] + jnp.arange(P, dtype=jnp.float32)[None, :]
        slot = jnp.arange(T)
        neg = jnp.asarray(-1e9, jnp.float32)
        # cache validity per lane: slot < pos0[b]  → (B, 1, 1, T)
        cache_mask = jnp.where(slot[None, :] < pos0[:, None], 0.0, neg)[:, None, None, :]
        k_chunks, v_chunks = [], []
        for l in range(cfg.n_layers):
            p = f"layer{l}."
            h = _rmsnorm(x, w[p + "ln1_g"])
            q = _split_heads(h @ w[p + "wq"], cfg)  # (B,H,P,dh)
            k = _split_heads(h @ w[p + "wk"], cfg)
            v = _split_heads(h @ w[p + "wv"], cfg)
            q = _rope(q, pos[:, None, :])
            k = _rope(k, pos[:, None, :])
            kc, vc = k_cache[l], v_cache[l]
            # scores vs cache (valid slots < pos0) and vs in-chunk (causal)
            sc = jnp.einsum("bhpd,bhtd->bhpt", q, kc) / math.sqrt(DH)
            sc = sc + cache_mask
            ss = jnp.einsum("bhpd,bhsd->bhps", q, k) / math.sqrt(DH)
            causal = jnp.where(
                jnp.arange(P)[:, None] >= jnp.arange(P)[None, :], 0.0, neg
            )
            ss = ss + causal[None, None]
            att = jax.nn.softmax(jnp.concatenate([sc, ss], axis=-1), axis=-1)
            ctx = jnp.einsum("bhpt,bhtd->bhpd", att[..., :T], vc) + jnp.einsum(
                "bhps,bhsd->bhpd", att[..., T:], v
            )
            ctx = ctx.transpose(0, 2, 1, 3).reshape(b, P, H * DH)
            x = x + ctx @ w[p + "wo"]
            h2 = _rmsnorm(x, w[p + "ln2_g"])
            x = x + (jax.nn.silu(h2 @ w[p + "w1"]) @ w[p + "w2"])
            k_chunks.append(k)
            v_chunks.append(v)
        x = _rmsnorm(x, w["ln_f_g"])
        logits = x @ w["unembed"]  # (B, P, vocab)
        return (logits, jnp.stack(k_chunks), jnp.stack(v_chunks))

    return f


def prefill_example_args(cfg: ModelConfig, batch: int):
    P, L, H, T, DH = cfg.prefill_chunk, cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head
    args = [
        jax.ShapeDtypeStruct((batch, P), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((L, batch, H, T, DH), jnp.float32),
        jax.ShapeDtypeStruct((L, batch, H, T, DH), jnp.float32),
    ]
    args += [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.weight_specs]
    return args


# --------------------------------------------------------------------------
# 3. Attention scorer (fidelity experiments)
# --------------------------------------------------------------------------

def attention_scorer(d_head: int):
    """f(q, k, v) -> (out, logits): single-query attention over a T-slot
    cache.  Used to measure attention-logit preservation under KV
    compression (§9.6 item 2)."""

    def f(q, k, v):
        logits = jnp.einsum("bhd,bhtd->bht", q, k) / math.sqrt(d_head)
        att = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bht,bhtd->bhd", att, v)
        return (out, logits)

    return f


def attention_example_args(batch: int, heads: int, t: int, d_head: int):
    return [
        jax.ShapeDtypeStruct((batch, heads, d_head), jnp.float32),
        jax.ShapeDtypeStruct((batch, heads, t, d_head), jnp.float32),
        jax.ShapeDtypeStruct((batch, heads, t, d_head), jnp.float32),
    ]
