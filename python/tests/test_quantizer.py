"""Scalar quantizer correctness: Lloyd–Max training, codebook quantize,
uniform quantizer, norm/direction split (paper §3, §5.6)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quantizer import (
    dequantize_codebook,
    gaussian_codebook,
    lloyd_max_codebook,
    lloyd_max_train,
    marginal_samples,
    norm_split,
    quant_dequant_codebook,
    quant_dequant_uniform,
    quantize_codebook,
    uniform_clip,
)


class TestLloydMax:
    @pytest.mark.parametrize("k", [2, 3, 4])
    @pytest.mark.parametrize("bits", [2, 3, 4])
    def test_codebook_sorted_and_sized(self, k, bits):
        cb = lloyd_max_codebook(k, bits)
        assert cb.shape == (2**bits,)
        assert np.all(np.diff(cb) > 0)

    @pytest.mark.parametrize("k", [2, 4])
    def test_codebook_symmetric(self, k):
        """The marginal f_k is symmetric, so Lloyd–Max levels should be
        (numerically) symmetric about zero."""
        cb = lloyd_max_codebook(k, 4)
        np.testing.assert_allclose(cb, -cb[::-1], atol=5e-3)

    def test_lloyd_beats_uniform_on_gaussian(self):
        """Sanity: trained codebook has lower distortion than a uniform
        grid with the same number of levels."""
        rng = np.random.default_rng(0)
        x = rng.standard_normal(50_000)
        cb = gaussian_codebook(3)
        xq = np.asarray(quant_dequant_codebook(jnp.asarray(x), cb))
        d_lloyd = np.mean((x - xq) ** 2)
        xu = np.asarray(quant_dequant_uniform(jnp.asarray(x), 3, 3.0))
        d_unif = np.mean((x - xu) ** 2)
        assert d_lloyd < d_unif

    def test_lloyd_distortion_decreases_with_bits(self):
        x = marginal_samples(4, n=20_001)
        prev = np.inf
        for bits in (2, 3, 4):
            cb = lloyd_max_codebook(4, bits)
            xq = np.asarray(quant_dequant_codebook(jnp.asarray(x), cb))
            d = np.mean((x - xq) ** 2)
            assert d < prev
            prev = d

    def test_training_deterministic(self):
        a = lloyd_max_train(marginal_samples(4, n=10_001), 8)
        b = lloyd_max_train(marginal_samples(4, n=10_001), 8)
        np.testing.assert_array_equal(a, b)


class TestMarginalSamples:
    def test_k2_is_arcsine_shaped(self):
        """k=2 marginal (paper eq. 37) has more mass near the extremes
        than k=4 (eq. 38)."""
        z2 = marginal_samples(2, n=50_001) / np.sqrt(2)
        z4 = marginal_samples(4, n=50_001) / np.sqrt(4)
        # P(|z| > 0.9): arcsine ≈ 0.287, semicircle-like ≈ 0.048
        p2 = np.mean(np.abs(z2) > 0.9)
        p4 = np.mean(np.abs(z4) > 0.9)
        assert p2 > 0.2 and p4 < 0.1

    @pytest.mark.parametrize("k", [2, 3, 4])
    def test_unit_variance_scaling(self, k):
        """sqrt(k)-scaled marginal has unit second moment: E[z^2] = 1/k
        on the unit sphere coordinate (paper eq. 35)."""
        s = marginal_samples(k, n=100_001)
        np.testing.assert_allclose(np.mean(s**2), 1.0, rtol=2e-2)


class TestCodebookQuant:
    @settings(max_examples=30, deadline=None)
    @given(
        st.lists(
            st.floats(-5, 5, allow_nan=False, allow_infinity=False, width=32),
            min_size=1,
            max_size=64,
        ),
        st.integers(2, 4),
    )
    def test_idempotent(self, xs, bits):
        """Q(Q(x)) = Q(x): quantization is a projection."""
        cb = lloyd_max_codebook(4, bits)
        x = jnp.asarray(xs, dtype=jnp.float32)
        once = quant_dequant_codebook(x, cb)
        twice = quant_dequant_codebook(once, cb)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    def test_output_in_codebook(self):
        cb = lloyd_max_codebook(4, 2)
        x = jnp.asarray(np.linspace(-3, 3, 101), dtype=jnp.float32)
        out = np.asarray(quant_dequant_codebook(x, cb))
        cbf = np.asarray(cb, dtype=np.float32)
        assert np.all(np.isin(out, cbf))

    def test_nearest_neighbor(self):
        """Boundary-search quantization equals brute-force nearest level."""
        cb = lloyd_max_codebook(4, 3)
        x = np.linspace(-4, 4, 1001)
        idx = np.asarray(quantize_codebook(jnp.asarray(x), cb))
        brute = np.argmin(np.abs(x[:, None] - np.asarray(cb)[None]), axis=1)
        np.testing.assert_array_equal(idx, brute)

    def test_index_range(self):
        cb = lloyd_max_codebook(2, 4)
        x = jnp.asarray(np.linspace(-10, 10, 999))
        idx = np.asarray(quantize_codebook(x, cb))
        assert idx.min() >= 0 and idx.max() <= 15

    def test_dequantize_roundtrip(self):
        cb = lloyd_max_codebook(4, 3)
        idx = jnp.asarray(np.arange(8), dtype=jnp.int32)
        out = np.asarray(dequantize_codebook(idx, cb, jnp.float32))
        np.testing.assert_allclose(out, np.asarray(cb, np.float32))


class TestUniformQuant:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 4))
    def test_levels_count(self, bits):
        clip = 2.0
        x = jnp.asarray(np.linspace(-3, 3, 4001), dtype=jnp.float32)
        out = np.asarray(quant_dequant_uniform(x, bits, clip))
        assert len(np.unique(out)) <= 2**bits

    def test_outputs_within_clip(self):
        x = jnp.asarray(np.linspace(-100, 100, 101), dtype=jnp.float32)
        out = np.asarray(quant_dequant_uniform(x, 4, 1.5))
        assert np.all(np.abs(out) <= 1.5)

    def test_clip_scale(self):
        assert uniform_clip(4, 4) == pytest.approx(2.0)
        assert uniform_clip(2, 2) == pytest.approx(np.sqrt(2.0))


class TestNormSplit:
    def test_reconstruction(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((32, 16)))
        rho, xbar = norm_split(x)
        np.testing.assert_allclose(np.asarray(rho * xbar), np.asarray(x), atol=1e-12)

    def test_unit_directions(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((32, 16)))
        _, xbar = norm_split(x)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(xbar), axis=-1), 1.0, rtol=1e-7
        )

    def test_zero_vector_safe(self):
        rho, xbar = norm_split(jnp.zeros((2, 8)))
        assert np.all(np.isfinite(np.asarray(xbar)))
        np.testing.assert_allclose(np.asarray(rho), 0.0)
