"""AOT path: HLO text emission, tensorfile format, manifest integrity."""

import json
import os
import struct

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


class TestHloText:
    def test_simple_graph_lowers_to_hlo_text(self):
        def f(x):
            return (x * 2.0 + 1.0,)

        lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ROOT" in text

    def test_stage1_graph_lowers(self):
        f = M.stage1_graph("full", 2)
        args = M.stage1_example_args("full", 8, 32)
        text = aot.to_hlo_text(jax.jit(f).lower(*args))
        assert "HloModule" in text
        # pallas interpret-mode must lower to plain HLO — no custom calls
        # that the CPU PJRT client can't execute
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


class TestTensorfile:
    def test_roundtrip_layout(self, tmp_path):
        path = tmp_path / "t.bin"
        a = np.arange(6, dtype=np.float32).reshape(2, 3)
        b = np.ones(4, dtype=np.float32)
        aot.write_tensorfile(str(path), [("a", a), ("b", b)])
        raw = path.read_bytes()
        assert raw[:8] == b"ISOQTNSR"
        version, count = struct.unpack_from("<II", raw, 8)
        assert (version, count) == (1, 2)
        # first tensor record
        name_len = struct.unpack_from("<I", raw, 16)[0]
        assert raw[20 : 20 + name_len] == b"a"

    def test_f32_payload_bytes(self, tmp_path):
        path = tmp_path / "t.bin"
        a = np.asarray([1.5, -2.0], dtype=np.float32)
        aot.write_tensorfile(str(path), [("x", a)])
        raw = path.read_bytes()
        assert a.tobytes() in raw


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        """Use the real artifacts if present (built by `make artifacts`);
        otherwise build a minimal manifest in a temp dir."""
        repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        path = os.path.join(repo, "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_model_geometry(self, manifest):
        m = manifest["model"]
        cfg = M.ModelConfig()
        assert m["d_head"] == cfg.d_head
        assert m["n_params"] == cfg.n_params()
        assert m["prefill_chunk"] == cfg.prefill_chunk

    def test_all_artifacts_exist_with_hlo(self, manifest):
        repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        for a in manifest["artifacts"]:
            p = os.path.join(repo, "artifacts", a["file"])
            assert os.path.exists(p), a["file"]
            with open(p) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_stage1_artifacts_cover_paper_bit_range(self, manifest):
        stage1 = [a for a in manifest["artifacts"] if a["meta"]["kind"] == "stage1"]
        bits = {a["meta"]["bits"] for a in stage1}
        variants = {a["meta"]["variant"] for a in stage1}
        assert {2, 4}.issubset(bits)
        assert {"full", "fast", "2d", "rotor"}.issubset(variants)

    def test_input_specs_match_model(self, manifest):
        dec = next(a for a in manifest["artifacts"] if a["name"] == "decode_step")
        m = manifest["model"]
        b = m["serve_batch"]
        names = [i["name"] for i in dec["inputs"]]
        assert names[:4] == ["tok", "pos", "k_cache", "v_cache"]
        assert dec["inputs"][0]["shape"] == [b]
        assert dec["inputs"][1]["shape"] == [b]
        assert dec["inputs"][2]["shape"] == [
            m["n_layers"], b, m["n_heads"], m["max_seq"], m["d_head"]
        ]
        # weights follow in spec order
        spec_names = [w["name"] for w in manifest["weight_specs"]]
        assert names[4:] == spec_names

    def test_weights_file_loads(self, manifest):
        repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        p = os.path.join(repo, "artifacts", manifest["weights"])
        assert os.path.exists(p)
        size = os.path.getsize(p)
        # at least 4 bytes per param
        assert size >= manifest["model"]["n_params"] * 4
