"""Algebraic properties of the quaternion layer (paper §4, Prop. 1)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quaternion as quat


def _rand_quat(rng, n=1):
    q = rng.standard_normal((n, 4))
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


finite_quat = st.lists(
    st.floats(-10, 10, allow_nan=False, allow_infinity=False, width=32),
    min_size=4, max_size=4,
)


class TestHamilton:
    def test_identity_element(self):
        e = jnp.asarray([1.0, 0.0, 0.0, 0.0])
        q = jnp.asarray([0.3, -0.5, 0.7, 0.1])
        np.testing.assert_allclose(quat.hamilton(e, q), q, atol=1e-7)
        np.testing.assert_allclose(quat.hamilton(q, e), q, atol=1e-7)

    def test_ijk_relations(self):
        """i^2 = j^2 = k^2 = ijk = -1 (paper eq. 4)."""
        i = jnp.asarray([0.0, 1.0, 0.0, 0.0])
        j = jnp.asarray([0.0, 0.0, 1.0, 0.0])
        k = jnp.asarray([0.0, 0.0, 0.0, 1.0])
        minus1 = jnp.asarray([-1.0, 0.0, 0.0, 0.0])
        for u in (i, j, k):
            np.testing.assert_allclose(quat.hamilton(u, u), minus1, atol=1e-7)
        np.testing.assert_allclose(
            quat.hamilton(quat.hamilton(i, j), k), minus1, atol=1e-7
        )

    def test_noncommutative(self):
        i = jnp.asarray([0.0, 1.0, 0.0, 0.0])
        j = jnp.asarray([0.0, 0.0, 1.0, 0.0])
        ij = quat.hamilton(i, j)
        ji = quat.hamilton(j, i)
        np.testing.assert_allclose(ij, -ji, atol=1e-7)
        assert not np.allclose(ij, ji)

    @settings(max_examples=50, deadline=None)
    @given(finite_quat, finite_quat, finite_quat)
    def test_associativity(self, a, b, c):
        a, b, c = (jnp.asarray(v, dtype=jnp.float64) for v in (a, b, c))
        lhs = quat.hamilton(quat.hamilton(a, b), c)
        rhs = quat.hamilton(a, quat.hamilton(b, c))
        np.testing.assert_allclose(lhs, rhs, atol=1e-9, rtol=1e-9)

    @settings(max_examples=50, deadline=None)
    @given(finite_quat, finite_quat)
    def test_norm_multiplicative(self, a, b):
        a, b = jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64)
        prod = quat.hamilton(a, b)
        na, nb = np.linalg.norm(a), np.linalg.norm(b)
        np.testing.assert_allclose(np.linalg.norm(prod), na * nb, rtol=1e-9)

    @settings(max_examples=50, deadline=None)
    @given(finite_quat, finite_quat)
    def test_conjugate_antihomomorphism(self, a, b):
        """conj(ab) = conj(b) conj(a)."""
        a, b = jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64)
        lhs = quat.conjugate(quat.hamilton(a, b))
        rhs = quat.hamilton(quat.conjugate(b), quat.conjugate(a))
        np.testing.assert_allclose(lhs, rhs, atol=1e-9)


class TestSandwich:
    """Prop. 1: T(v) = qL v conj(qR) is orthogonal with the stated inverse."""

    def test_norm_preserving(self):
        rng = np.random.default_rng(0)
        ql = jnp.asarray(_rand_quat(rng, 32))
        qr = jnp.asarray(_rand_quat(rng, 32))
        v = jnp.asarray(rng.standard_normal((32, 4)))
        out = quat.sandwich(ql, v, qr)
        np.testing.assert_allclose(
            np.linalg.norm(out, axis=-1), np.linalg.norm(v, axis=-1), rtol=1e-10
        )

    def test_inverse_roundtrip(self):
        rng = np.random.default_rng(1)
        ql = jnp.asarray(_rand_quat(rng, 16))
        qr = jnp.asarray(_rand_quat(rng, 16))
        v = jnp.asarray(rng.standard_normal((16, 4)))
        rt = quat.sandwich_inv(ql, quat.sandwich(ql, v, qr), qr)
        np.testing.assert_allclose(rt, v, atol=1e-10)

    def test_double_cover(self):
        """(qL, qR) and (-qL, -qR) induce the same SO(4) element (eq. 13)."""
        rng = np.random.default_rng(2)
        ql = jnp.asarray(_rand_quat(rng, 8))
        qr = jnp.asarray(_rand_quat(rng, 8))
        v = jnp.asarray(rng.standard_normal((8, 4)))
        np.testing.assert_allclose(
            quat.sandwich(ql, v, qr), quat.sandwich(-ql, v, -qr), atol=1e-12
        )

    def test_so4_matrix_orthogonal_det_one(self):
        rng = np.random.default_rng(3)
        for _ in range(10):
            ql = jnp.asarray(_rand_quat(rng)[0])
            qr = jnp.asarray(_rand_quat(rng)[0])
            m = np.asarray(quat.so4_matrix(ql, qr), dtype=np.float64)
            np.testing.assert_allclose(m @ m.T, np.eye(4), atol=1e-7)
            np.testing.assert_allclose(np.linalg.det(m), 1.0, rtol=1e-6)

    def test_matrix_matches_sandwich(self):
        rng = np.random.default_rng(4)
        ql = jnp.asarray(_rand_quat(rng)[0])
        qr = jnp.asarray(_rand_quat(rng)[0])
        m = np.asarray(quat.so4_matrix(ql, qr))
        v = rng.standard_normal(4)
        np.testing.assert_allclose(
            m @ v, np.asarray(quat.sandwich(ql, jnp.asarray(v), qr)), atol=1e-6
        )

    def test_left_isoclinic_is_so3_subgroup(self):
        """Fast mode: left multiplication preserves the quaternion norm and
        roundtrips (paper §5.3)."""
        rng = np.random.default_rng(5)
        ql = jnp.asarray(_rand_quat(rng, 16))
        v = jnp.asarray(rng.standard_normal((16, 4)))
        y = quat.left_mul(ql, v)
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(v, axis=-1), rtol=1e-10
        )
        np.testing.assert_allclose(quat.left_mul_inv(ql, y), v, atol=1e-10)


class TestNormalize:
    def test_unit_norm(self):
        rng = np.random.default_rng(6)
        u = jnp.asarray(rng.standard_normal((100, 4)))
        q = quat.normalize(u)
        np.testing.assert_allclose(np.linalg.norm(q, axis=-1), 1.0, rtol=1e-7)

    def test_eps_guard(self):
        q = quat.normalize(jnp.zeros((1, 4)))
        assert np.all(np.isfinite(q))
