"""Statistical reproduction of paper §5.7: the probabilistic case for
random subspace rotations (eq. 35–40)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import params, quaternion as quat


def _haar_so_k(rng, n, k):
    """n independent Haar SO(k) matrices, shape (n, k, k)."""
    a = rng.standard_normal((n, k, k))
    q, r = np.linalg.qr(a)
    # fix the sign convention to get Haar O(k), then restrict to SO(k)
    q = q * np.sign(np.einsum("nii->ni", r))[:, None, :]
    det = np.linalg.det(q)
    q[det < 0, :, 0] *= -1.0
    return q


class TestEnergyRedistribution:
    """eq. 35: E[y_j | x] = 0 and E[y_j^2 | x] = r^2 / k."""

    @pytest.mark.parametrize("k", [2, 3, 4])
    def test_moments(self, k):
        rng = np.random.default_rng(0)
        # one fixed, deliberately anisotropic block
        x0 = np.zeros(k)
        x0[0] = 2.0  # all energy on one coordinate
        n = 40_000
        rots = _haar_so_k(rng, n, k)           # independent per replica
        ys = np.einsum("nij,j->ni", rots, x0)
        np.testing.assert_allclose(ys.mean(axis=0), 0.0, atol=0.05)
        np.testing.assert_allclose(
            (ys**2).mean(axis=0), 4.0 / k, rtol=0.08
        )

    def test_quaternion_sandwich_is_haar_when_pair_is_haar(self):
        """Haar (qL, qR) → the image of a fixed vector is uniform on the
        sphere of its radius: checks coordinate moments of eq. 35 for the
        actual IsoQuant-Full transform."""
        rng = np.random.default_rng(1)
        n = 40_000
        ql = jnp.asarray(params.haar_s3(rng, n))
        qr = jnp.asarray(params.haar_s3(rng, n))
        v = jnp.tile(jnp.asarray([2.0, 0.0, 0.0, 0.0]), (n, 1))
        y = np.asarray(quat.sandwich(ql, v, qr))
        np.testing.assert_allclose(np.linalg.norm(y, axis=-1), 2.0, rtol=1e-6)
        np.testing.assert_allclose(y.mean(axis=0), 0.0, atol=0.05)
        np.testing.assert_allclose((y**2).mean(axis=0), 1.0, rtol=0.08)


class TestMarginalLaws:
    """eq. 36–38: arcsine (k=2) vs semicircle-like (k=4) marginals."""

    def test_k4_less_extreme_than_k2(self):
        rng = np.random.default_rng(2)
        n = 50_000
        # k = 2
        th = rng.uniform(0, 2 * np.pi, n)
        z2 = np.cos(th)
        # k = 4: first coordinate of a Haar unit quaternion
        z4 = params.haar_s3(rng, n)[:, 0]
        assert np.mean(np.abs(z2) > 0.9) > 3 * np.mean(np.abs(z4) > 0.9)

    def test_k4_density_vanishes_at_boundary(self):
        """f_4(z) = (2/pi) sqrt(1-z^2): mass in |z| in [0.99, 1] should be
        ~ integral ≈ 2/pi * 2 * ∫_{.99}^{1} sqrt(1-z²)dz ≈ 2.4e-3."""
        rng = np.random.default_rng(3)
        z4 = params.haar_s3(rng, 200_000)[:, 0]
        frac = np.mean(np.abs(z4) > 0.99)
        assert frac < 0.01

    def test_k2_arcsine_cdf(self):
        """Kolmogorov–Smirnov check of the arcsine law for k=2."""
        rng = np.random.default_rng(4)
        th = rng.uniform(0, 2 * np.pi, 100_000)
        z = np.sort(np.cos(th))
        emp = np.arange(1, z.size + 1) / z.size
        want = 0.5 + np.arcsin(z) / np.pi
        assert np.max(np.abs(emp - want)) < 0.01


class TestCovarianceIsotropization:
    """eq. 40: E_R[R Σ Rᵀ] is block-diagonal with tr(Σ_ii)/k · I_k blocks."""

    @pytest.mark.parametrize("k", [2, 4])
    def test_expected_covariance(self, k):
        rng = np.random.default_rng(5)
        d = 8
        # random correlated covariance
        a = rng.standard_normal((d, d))
        sigma = a @ a.T
        n_mc = 4000
        acc = np.zeros((d, d))
        for _ in range(n_mc):
            blocks = []
            for _ in range(d // k):
                g = rng.standard_normal((k, k))
                q, r = np.linalg.qr(g)
                q = q * np.sign(np.diag(r))
                if np.linalg.det(q) < 0:
                    q[:, 0] = -q[:, 0]
                blocks.append(q)
            rmat = np.zeros((d, d))
            for i, qb in enumerate(blocks):
                rmat[i * k : (i + 1) * k, i * k : (i + 1) * k] = qb
            acc += rmat @ sigma @ rmat.T
        acc /= n_mc
        want = np.zeros((d, d))
        for i in range(d // k):
            sl = slice(i * k, (i + 1) * k)
            want[sl, sl] = np.trace(sigma[sl, sl]) / k * np.eye(k)
        # off-diagonal blocks vanish in expectation; diagonal blocks isotropize
        np.testing.assert_allclose(acc, want, atol=0.35 * np.abs(sigma).max())

    def test_rotation_helps_correlated_data(self):
        """The operational consequence of eq. 40: on strongly
        block-correlated inputs, random 4D rotation lowers quantization
        MSE vs no rotation."""
        from compile.kernels import isoquant, ref

        rng = np.random.default_rng(6)
        d, b = 128, 2
        # energy concentrated on one coordinate per 4-block: the worst case
        # for coordinate-wise quantization in the original basis, the case
        # random rotation fixes by isotropizing each block (eq. 40)
        base = rng.standard_normal((2048, d // 4, 1))
        x = (base * np.asarray([1.0, 0.05, 0.03, 0.02])).reshape(2048, d)
        x += 0.01 * rng.standard_normal((2048, d))
        xj = jnp.asarray(x, dtype=jnp.float32)
        ql, qr = params.quaternion_pairs(d, 9)
        mse_rot = float(ref.mse(xj, isoquant.isoquant_full(xj, jnp.asarray(ql), jnp.asarray(qr), b)))
        mse_id = float(ref.mse(xj, ref.identity(xj, b)))
        assert mse_rot < mse_id
