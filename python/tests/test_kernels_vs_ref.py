"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, bit widths, and quantizer families for
every kernel; each draw asserts allclose against ``ref.py``.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense_rot, isoquant, params, ref, rotor3d

# Batch sizes exercise tile selection (B < tile, B == tile, B = multiple
# of tile, odd multiples); dims cover the paper's sweep plus small cases.
BATCHES = [1, 2, 8, 64, 96, 256]
DIMS_4D = [8, 64, 128, 256, 512]
DIMS_2D = [2, 64, 128, 256, 512]
DIMS_ANY = [64, 128, 256]

dtype_st = st.sampled_from([jnp.float32, jnp.float16])
bits_st = st.integers(2, 4)
quant_st = st.sampled_from(["lloyd", "uniform"])


def _tol(dtype):
    return dict(atol=2e-3, rtol=2e-2) if dtype == jnp.float16 else dict(atol=1e-5, rtol=1e-4)


def _assert_matches(got, want, dtype):
    """f32: strict allclose.  f16: interpret-mode Pallas may evaluate at a
    slightly different intermediate precision than pure jnp, so inputs
    sitting exactly on a codebook boundary can flip to the adjacent level
    — allow ≤1% of elements to differ by up to one quantization step,
    with everything else tightly matched."""
    got = np.asarray(got, dtype=np.float64)
    want = np.asarray(want, dtype=np.float64)
    if dtype == jnp.float16:
        err = np.abs(got - want)
        tol = 2e-3 + 2e-2 * np.abs(want)
        n_bad = int(np.sum(err > tol))
        # one flipped code fans out to all block_k coords through the
        # inverse rotation, and tiny tensors make percentages meaningless
        allowed = max(8, int(0.02 * err.size))
        assert n_bad <= allowed, f"{n_bad}/{err.size} elements off (max {err.max()})"
        # boundary flips are bounded: per-element error is at most one
        # codebook gap scaled by ρ/√d, so the *aggregate* energy of the
        # mismatch must stay a small fraction of the signal energy
        power = float(np.mean(want**2)) + 1e-12
        assert float(np.mean(err**2)) < 0.02 * power, (
            f"flip energy {np.mean(err**2)} vs power {power}"
        )
    else:
        np.testing.assert_allclose(got, want, **_tol(dtype))


def _input(rng, b, d, dtype):
    x = rng.standard_normal((b, d)) * rng.uniform(0.3, 3.0)
    return jnp.asarray(x, dtype=dtype)


class TestIsoQuantFull:
    @settings(max_examples=40, deadline=None)
    @given(
        b=st.sampled_from(BATCHES),
        d=st.sampled_from(DIMS_4D),
        bits=bits_st,
        dtype=dtype_st,
        quant=quant_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, bits, dtype, quant, seed):
        rng = np.random.default_rng(seed)
        x = _input(rng, b, d, dtype)
        ql, qr = params.quaternion_pairs(d, seed)
        want = ref.isoquant_full(x, jnp.asarray(ql), jnp.asarray(qr), bits, quant)
        got = isoquant.isoquant_full(x, jnp.asarray(ql), jnp.asarray(qr), bits, quant)
        _assert_matches(got, want, dtype)


class TestIsoQuantFast:
    @settings(max_examples=40, deadline=None)
    @given(
        b=st.sampled_from(BATCHES),
        d=st.sampled_from(DIMS_4D),
        bits=bits_st,
        dtype=dtype_st,
        quant=quant_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, bits, dtype, quant, seed):
        rng = np.random.default_rng(seed)
        x = _input(rng, b, d, dtype)
        ql = params.quaternion_single(d, seed)
        want = ref.isoquant_fast(x, jnp.asarray(ql), bits, quant)
        got = isoquant.isoquant_fast(x, jnp.asarray(ql), bits, quant)
        _assert_matches(got, want, dtype)


class TestIsoQuant2D:
    @settings(max_examples=40, deadline=None)
    @given(
        b=st.sampled_from(BATCHES),
        d=st.sampled_from(DIMS_2D),
        bits=bits_st,
        dtype=dtype_st,
        quant=quant_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, bits, dtype, quant, seed):
        rng = np.random.default_rng(seed)
        x = _input(rng, b, d, dtype)
        th = params.planar_angles(d, seed)
        want = ref.isoquant_2d(x, jnp.asarray(th), bits, quant)
        got = isoquant.isoquant_2d(x, jnp.asarray(th), bits, quant)
        _assert_matches(got, want, dtype)


class TestRotorQuant:
    @settings(max_examples=30, deadline=None)
    @given(
        b=st.sampled_from(BATCHES),
        d=st.sampled_from([63, 64, 65, 128, 256]),  # tails 0, 1, 2
        bits=bits_st,
        dtype=dtype_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, bits, dtype, seed):
        rng = np.random.default_rng(seed)
        x = _input(rng, b, d, dtype)
        q3, tt = params.rotor3_params(d, seed)
        want = ref.rotorquant(x, jnp.asarray(q3), jnp.asarray(tt), bits)
        got = rotor3d.rotorquant(x, jnp.asarray(q3), jnp.asarray(tt), bits)
        _assert_matches(got, want, dtype)

    def test_d128_partition_is_42_blocks_plus_2d_tail(self):
        """The paper's motivating example (§1)."""
        nfull, tail = params.g3(128)
        assert (nfull, tail) == (42, 2)


class TestDenseRotation:
    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([8, 64]),
        d=st.sampled_from(DIMS_ANY),
        bits=bits_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, bits, seed):
        rng = np.random.default_rng(seed)
        x = _input(rng, b, d, jnp.float32)
        m = params.dense_orthogonal(d, seed)
        want = ref.dense_rotation(x, jnp.asarray(m), bits)
        got = dense_rot.dense_rotation(x, jnp.asarray(m), bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)


class TestPipelineInvariants:
    """Stage-1 invariants that hold for every variant (paper Alg. 1)."""

    @pytest.mark.parametrize("bits", [2, 3, 4])
    def test_reconstruction_norm_bounded(self, bits):
        """The reconstruction of a normalized vector has norm ≤ ~1 + quant
        error: rotations are isometries, so only Q can change the norm."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((128, 128)), dtype=jnp.float32)
        ql, qr = params.quaternion_pairs(128, 7)
        xhat = isoquant.isoquant_full(x, jnp.asarray(ql), jnp.asarray(qr), bits)
        rho = np.linalg.norm(np.asarray(x), axis=-1)
        rho_hat = np.linalg.norm(np.asarray(xhat), axis=-1)
        # quantization perturbs the unit direction by bounded error
        assert np.all(rho_hat <= rho * 1.6 + 1e-6)

    def test_full_mse_improves_with_bits(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((512, 128)), dtype=jnp.float32)
        ql, qr = params.quaternion_pairs(128, 3)
        mses = [
            float(ref.mse(x, isoquant.isoquant_full(x, jnp.asarray(ql), jnp.asarray(qr), b)))
            for b in (2, 3, 4)
        ]
        assert mses[0] > mses[1] > mses[2]

    def test_scaling_equivariance(self):
        """xhat(c·x) = c·xhat(x): the norm split makes stage-1 scale-
        equivariant (paper eq. 3)."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((16, 64)), dtype=jnp.float32)
        ql, qr = params.quaternion_pairs(64, 5)
        a = isoquant.isoquant_full(3.0 * x, jnp.asarray(ql), jnp.asarray(qr), 4)
        b = 3.0 * isoquant.isoquant_full(x, jnp.asarray(ql), jnp.asarray(qr), 4)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)

    def test_identity_rotation_reduces_to_plain_quant(self):
        """With qL = qR = 1 the Full pipeline is plain scalar quantization."""
        rng = np.random.default_rng(3)
        d = 64
        x = jnp.asarray(rng.standard_normal((8, d)), dtype=jnp.float32)
        e = np.zeros((d // 4, 4))
        e[:, 0] = 1.0
        got = isoquant.isoquant_full(x, jnp.asarray(e), jnp.asarray(e), 4)
        want = ref.identity(x, 4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_full_with_qr_identity_equals_fast(self):
        """Fast is Full restricted to qR = 1 (paper §5.3)."""
        rng = np.random.default_rng(4)
        d = 128
        x = jnp.asarray(rng.standard_normal((8, d)), dtype=jnp.float32)
        ql = params.quaternion_single(d, 11)
        e = np.zeros((d // 4, 4))
        e[:, 0] = 1.0
        a = isoquant.isoquant_full(x, jnp.asarray(ql), jnp.asarray(e), 3)
        b = isoquant.isoquant_fast(x, jnp.asarray(ql), 3)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
