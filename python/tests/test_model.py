"""L2 model correctness: decode/prefill consistency, attention masking,
rope properties, weight-spec contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def cfg():
    # small config keeps these tests fast; the AOT config is larger
    return M.ModelConfig(
        vocab=64, d_model=32, n_heads=2, d_head=16, n_layers=2, d_ff=64,
        max_seq=32, prefill_chunk=8,
    )


@pytest.fixture(scope="module")
def weights(cfg):
    return [jnp.asarray(w) for w in M.init_weights(cfg, seed=1)]


def zero_caches(cfg, b):
    shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.d_head)
    return jnp.zeros(shape), jnp.zeros(shape)


class TestWeights:
    def test_spec_order_and_count(self, cfg):
        specs = cfg.weight_specs
        assert specs[0][0] == "embed"
        assert specs[-1][0] == "unembed"
        assert len(specs) == 3 + 8 * cfg.n_layers

    def test_param_count(self, cfg):
        assert cfg.n_params() == sum(
            int(np.prod(s)) for _, s in cfg.weight_specs
        )

    def test_deterministic_init(self, cfg):
        a = M.init_weights(cfg, seed=0)
        b = M.init_weights(cfg, seed=0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_layernorm_gains_ones(self, cfg):
        for (name, _), w in zip(cfg.weight_specs, M.init_weights(cfg)):
            if name.endswith("_g"):
                np.testing.assert_array_equal(w, np.ones_like(w))


class TestDecodeStep:
    def test_shapes(self, cfg, weights):
        b = 3
        f = M.decode_step(cfg)
        k, v = zero_caches(cfg, b)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        logits, k_new, v_new = f(tok, pos, k, v, *weights)
        assert logits.shape == (b, cfg.vocab)
        assert k_new.shape == (cfg.n_layers, b, cfg.n_heads, cfg.d_head)
        assert v_new.shape == k_new.shape
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_lanes_independent(self, cfg, weights):
        """Changing lane 1's token must not change lane 0's logits —
        the continuous-batching isolation property."""
        b = 2
        f = jax.jit(M.decode_step(cfg))
        k, v = zero_caches(cfg, b)
        pos = jnp.asarray([3, 7], jnp.int32)
        la, _, _ = f(jnp.asarray([5, 9], jnp.int32), pos, k, v, *weights)
        lb, _, _ = f(jnp.asarray([5, 33], jnp.int32), pos, k, v, *weights)
        np.testing.assert_allclose(la[0], lb[0], atol=1e-6)
        assert not np.allclose(la[1], lb[1])

    def test_cache_masking(self, cfg, weights):
        """Slots at or beyond a lane's pos must not influence its output."""
        b = 2
        f = jax.jit(M.decode_step(cfg))
        k, v = zero_caches(cfg, b)
        rng = np.random.default_rng(0)
        # poison slots >= pos with huge values
        k = k.at[:, :, :, 5:, :].set(1e3)
        v = v.at[:, :, :, 5:, :].set(1e3)
        pos = jnp.asarray([5, 5], jnp.int32)
        tok = jnp.asarray([1, 2], jnp.int32)
        la, _, _ = f(tok, pos, k, v, *weights)
        kc, vc = zero_caches(cfg, b)
        lb, _, _ = f(tok, pos, kc, vc, *weights)
        np.testing.assert_allclose(la, lb, atol=1e-5)
        del rng

    def test_position_changes_output(self, cfg, weights):
        """RoPE: same token at different positions gives different K."""
        b = 1
        f = jax.jit(M.decode_step(cfg))
        k, v = zero_caches(cfg, b)
        tok = jnp.asarray([7], jnp.int32)
        _, k0, _ = f(tok, jnp.asarray([0], jnp.int32), k, v, *weights)
        _, k5, _ = f(tok, jnp.asarray([5], jnp.int32), k, v, *weights)
        assert not np.allclose(k0, k5)
        # layer 0's K depends on pos only through RoPE (a rotation), so
        # its norm is preserved; deeper layers legitimately differ
        # because pos changes how many (zero) cache slots are attended.
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(k0)[0].ravel()),
            np.linalg.norm(np.asarray(k5)[0].ravel()),
            rtol=1e-5,
        )


class TestPrefillDecodeConsistency:
    def test_prefill_matches_stepwise_decode(self, cfg, weights):
        """The chunked prefill graph and repeated decode steps must agree
        on next-token logits — the invariant the engine relies on."""
        b = 2
        rng = np.random.default_rng(3)
        plen = 5
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)

        # path A: prefill
        fp = jax.jit(M.prefill_chunk(cfg))
        k, v = zero_caches(cfg, b)
        toks = np.zeros((b, cfg.prefill_chunk), np.int32)
        toks[0, :plen] = prompt
        logits_a, k_chunk, v_chunk = fp(
            jnp.asarray(toks), jnp.zeros((b,), jnp.int32), k, v, *weights
        )
        la = np.asarray(logits_a)[0, plen - 1]

        # path B: stepwise decode with exact cache writes
        fd = jax.jit(M.decode_step(cfg))
        k_cache, v_cache = zero_caches(cfg, b)
        lb = None
        for step, t in enumerate(prompt):
            tok = jnp.asarray([t, 0], jnp.int32)
            pos = jnp.asarray([step, 0], jnp.int32)
            logits, k_new, v_new = fd(tok, pos, k_cache, v_cache, *weights)
            k_cache = k_cache.at[:, 0, :, step, :].set(k_new[:, 0])
            v_cache = v_cache.at[:, 0, :, step, :].set(v_new[:, 0])
            lb = np.asarray(logits)[0]
        np.testing.assert_allclose(la, lb, atol=1e-4, rtol=1e-4)

    def test_prefill_kv_matches_decode_kv(self, cfg, weights):
        """The K/V the prefill graph returns for each prompt position must
        equal what decode_step computes at that position."""
        b = 1
        cfg1 = M.ModelConfig(
            vocab=cfg.vocab, d_model=cfg.d_model, n_heads=cfg.n_heads,
            d_head=cfg.d_head, n_layers=cfg.n_layers, d_ff=cfg.d_ff,
            max_seq=cfg.max_seq, prefill_chunk=cfg.prefill_chunk,
        )
        fp = jax.jit(M.prefill_chunk(cfg1))
        fd = jax.jit(M.decode_step(cfg1))
        prompt = np.asarray([3, 9, 11], np.int32)
        plen = len(prompt)
        k, v = zero_caches(cfg1, b)
        toks = np.zeros((b, cfg1.prefill_chunk), np.int32)
        toks[0, :plen] = prompt
        _, k_chunk, v_chunk = fp(
            jnp.asarray(toks), jnp.zeros((b,), jnp.int32), k, v, *weights
        )
        # decode position 0 must produce the same k as chunk position 0
        k_cache, v_cache = zero_caches(cfg1, b)
        _, k_new, _ = fd(
            jnp.asarray([prompt[0]], jnp.int32),
            jnp.asarray([0], jnp.int32),
            k_cache, v_cache, *weights,
        )
        np.testing.assert_allclose(
            np.asarray(k_chunk)[:, 0, :, 0, :], np.asarray(k_new)[:, 0], atol=1e-5
        )


class TestRope:
    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 3, 16)), jnp.float32)
        pos = jnp.asarray(rng.uniform(0, 100, (2, 1, 3)), jnp.float32)
        # _rope broadcasts pos[..., None] over the half-dim axis
        y = M._rope(x, pos[..., 0])
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_zero_position_identity(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
        y = M._rope(x, jnp.zeros((4,), jnp.float32))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)

    def test_rope_relative_property(self):
        """⟨rope(q, p1), rope(k, p2)⟩ depends only on p1 - p2."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal(16), jnp.float32)
        k = jnp.asarray(rng.standard_normal(16), jnp.float32)
        def dot(p1, p2):
            a = M._rope(q, jnp.asarray(float(p1)))
            b = M._rope(k, jnp.asarray(float(p2)))
            return float(jnp.dot(a, b))
        assert abs(dot(3, 1) - dot(10, 8)) < 1e-4
        assert abs(dot(0, 0) - dot(7, 7)) < 1e-4


class TestStage1Graphs:
    def test_graph_builders_all_variants(self):
        for variant in ["full", "fast", "2d", "rotor", "dense"]:
            f = M.stage1_graph(variant, 3)
            args = M.stage1_example_args(variant, 8, 64)
            lowered = jax.jit(f).lower(*args)
            assert lowered is not None

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            M.stage1_graph("nope", 4)
