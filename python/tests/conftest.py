"""Test configuration: enable x64 (the algebra property tests check
identities at double precision) and make ``compile.*`` importable when
pytest is invoked from the repository root."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_enable_x64", True)
